"""deepseek-moe-16b [moe]: fine-grained experts, 2 shared + 64 routed
top-6. [arXiv:2401.06066; hf]  28L d_model=2048 16H (kv=16) d_ff=1408
vocab=102400."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  d_expert=1408, capacity_factor=1.25, adaptive=True),
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=32, vocab_size=256,
                      moe=MoEConfig(num_experts=8, top_k=2,
                                    num_shared_experts=1, d_expert=32,
                                    capacity_factor=1.5, adaptive=True))
