"""llama4-scout-17b-a16e [moe]: 16 experts top-1 (+1 shared), early
fusion (text backbone only here).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (kv=8) d_ff=8192 vocab=202048."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=1, num_shared_experts=1,
                  d_expert=8192, capacity_factor=1.25, adaptive=True),
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=64, vocab_size=256,
                      head_dim=16,
                      moe=MoEConfig(num_experts=4, top_k=1,
                                    num_shared_experts=1, d_expert=64,
                                    capacity_factor=1.5, adaptive=True))
