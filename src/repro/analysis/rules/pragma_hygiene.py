"""``bad-pragma``: suppression pragmas must be well-formed.

A ``# repro:`` comment that fails to parse, names a rule that does
not exist, or omits the mandatory ``-- justification`` is a finding
in its own right — otherwise a typo'd pragma silently suppresses
nothing (or the author believes it suppresses something).
"""
from __future__ import annotations

from typing import List

from ..findings import Finding
from ..registry import Rule, register_rule

RULE_ID = "bad-pragma"


def check(ctx) -> List[Finding]:
    """Emit a finding for each malformed pragma in the file."""
    _allows, problems = ctx.pragma_info
    return [ctx.finding(line, RULE_ID, message)
            for line, message in problems]


register_rule(Rule(
    id=RULE_ID,
    description="`# repro:` pragmas must parse, name real rules, and "
                "carry a justification",
    check=check,
    relaxed=True,
))
