"""Unified executor registry: the fully-jit SPMD round must match the
host-driven round for every strategy and both executor backends, and
the Pallas kernels must work inside ``shard_map`` (the Gluon runtime).

This is the acceptance suite for the executor-registry refactor
(DESIGN.md section 3): one planner, two execution modes, two backends.
"""
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph as G
from repro.core.balancer import (BalancerConfig, RoundStats, relax,
                                 relax_spmd, make_plan)
from repro.core.frontier import single_source
from repro.core import operators as ops
from repro.core import gluon
from repro.core.partition import partition
from repro.core.apps import bfs, sssp, cc, pagerank

STRATS = ["vertex", "twc", "edge_lb", "alb"]


@pytest.fixture(scope="module", params=["rmat", "road"])
def graph(request):
    if request.param == "rmat":
        return G.rmat(9, 8, seed=3)
    return G.road_grid(16, seed=3)


def _sssp_round_inputs(g):
    src = G.highest_out_degree_vertex(g)
    v = g.num_vertices
    dist = jnp.full((v,), G.INF, jnp.int32).at[src].set(0)
    return dist, single_source(v, src)


# ---------------- single-round parity, all strategies x backends ----------

@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("strategy", STRATS)
def test_relax_spmd_matches_host_all_strategies(graph, strategy, use_pallas):
    dist, frontier = _sssp_round_inputs(graph)
    cfg = BalancerConfig(strategy=strategy, threshold=64,
                         use_pallas=use_pallas)
    host, _ = relax(graph, dist, dist, frontier, cfg, ops.SSSP_RELAX)
    spmd = relax_spmd(graph, dist, dist, frontier, cfg, ops.SSSP_RELAX)
    np.testing.assert_array_equal(np.asarray(host), np.asarray(spmd))


def test_spmd_pallas_matches_spmd_xla_round(graph):
    dist, frontier = _sssp_round_inputs(graph)
    outs = []
    for up in [False, True]:
        cfg = BalancerConfig(strategy="alb", threshold=64, use_pallas=up)
        outs.append(relax_spmd(graph, dist, dist, frontier, cfg,
                               ops.SSSP_RELAX))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


# ---------------- full apps in spmd mode, pallas vs xla -------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_apps_spmd_mode_match_host_mode(graph, use_pallas):
    """bfs/sssp/cc/pagerank driven by relax_spmd == host round labels."""
    src = G.highest_out_degree_vertex(graph)
    cfg = BalancerConfig(strategy="alb", threshold=64,
                         use_pallas=use_pallas)
    ref_cfg = BalancerConfig(strategy="alb", threshold=64)
    np.testing.assert_array_equal(
        np.asarray(sssp(graph, src, cfg, mode="spmd").labels),
        np.asarray(sssp(graph, src, ref_cfg).labels))
    np.testing.assert_array_equal(
        np.asarray(bfs(graph, src, cfg, mode="spmd").labels),
        np.asarray(bfs(graph, src, ref_cfg).labels))
    np.testing.assert_array_equal(
        np.asarray(cc(graph, cfg, mode="spmd").labels),
        np.asarray(cc(graph, ref_cfg).labels))
    # float scatter-add order differs between enumerations: allclose
    np.testing.assert_allclose(
        np.asarray(pagerank(graph, cfg=cfg, max_rounds=15, tol=0.0,
                            mode="spmd").labels),
        np.asarray(pagerank(graph, cfg=ref_cfg, max_rounds=15,
                            tol=0.0).labels), rtol=1e-5, atol=1e-8)


# ---------------- jit-safe instrumentation --------------------------------

def test_spmd_stats_match_host_stats():
    g = G.rmat(9, 8, seed=3)
    dist, frontier = _sssp_round_inputs(g)
    cfg = BalancerConfig(strategy="alb", threshold=64)
    _, hst = relax(g, dist, dist, frontier, cfg, ops.SSSP_RELAX,
                   collect_stats=True)
    _, dst = relax_spmd(g, dist, dist, frontier, cfg, ops.SSSP_RELAX,
                        collect_stats=True)
    sst = RoundStats.from_device(dst)
    assert sst.frontier_size == hst.frontier_size
    assert sst.edges_twc == hst.edges_twc
    assert sst.edges_lb == hst.edges_lb
    assert sst.lb_invoked == hst.lb_invoked
    np.testing.assert_array_equal(sst.tile_loads_lb, hst.tile_loads_lb)


def test_spmd_stats_inspector_adaptive_on_flat_graph():
    """road-style graph: the SPMD inspector must never fire the LB
    executor (Table 2 'negligible overhead' claim, now jit-safe)."""
    g = G.road_grid(20, seed=0)
    cfg = BalancerConfig(strategy="alb", threshold=64)
    out = sssp(g, 0, cfg, collect_stats=True, mode="spmd")
    assert out.stats
    assert all(not st.lb_invoked for st in out.stats)
    assert all(st.edges_lb == 0 for st in out.stats)


def test_spmd_stats_lb_fires_and_balances_on_power_law():
    g = G.rmat(9, 8, seed=3)
    src = G.highest_out_degree_vertex(g)
    cfg = BalancerConfig(strategy="alb", threshold=64)
    out = sssp(g, src, cfg, collect_stats=True, mode="spmd")
    fired = [st for st in out.stats if st.lb_invoked]
    assert fired
    for st in fired:
        assert st.edges_lb == st.tile_loads_lb.sum()
        assert st.tile_loads_lb.max() - st.tile_loads_lb.min() <= 1


# ---------------- pallas inside shard_map (the tentpole claim) ------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_gluon_runtime_runs_both_backends(graph, use_pallas):
    """The distributed round (shard_map over a 1-device mesh exercises
    the full machinery) must produce the reference labels with the
    Pallas kernels dispatched inside shard_map."""
    src = G.highest_out_degree_vertex(graph)
    mesh = gluon.device_mesh(1)
    sg, _ = partition(graph, 1, "oec")
    cfg = BalancerConfig(strategy="alb", threshold=64,
                         use_pallas=use_pallas)
    ref = sssp(graph, src, BalancerConfig(strategy="alb", threshold=64))
    labels, rounds, _ = gluon.sssp_distributed(sg, mesh, src, cfg)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref.labels))

    bref = bfs(graph, src, BalancerConfig(strategy="alb", threshold=64))
    blabels, _, _ = gluon.bfs_distributed(sg, mesh, src, cfg)
    np.testing.assert_array_equal(np.asarray(blabels),
                                  np.asarray(bref.labels))

    rg = G.reverse_graph(graph)
    srg, _ = partition(rg, 1, "oec")
    pref = pagerank(graph, max_rounds=10, tol=0.0)
    rank, _, _ = gluon.pagerank_distributed(srg, mesh, graph.out_degrees(),
                                            cfg=cfg, max_rounds=10, tol=0.0)
    np.testing.assert_allclose(np.asarray(rank), np.asarray(pref.labels),
                               atol=1e-6)


def test_gluon_collect_stats_through_shard_map():
    g = G.rmat(9, 8, seed=3)
    src = G.highest_out_degree_vertex(g)
    mesh = gluon.device_mesh(1)
    sg, _ = partition(g, 1, "oec")
    cfg = BalancerConfig(strategy="alb", threshold=64)
    labels, rounds, _, stats = gluon.sssp_distributed(
        sg, mesh, src, cfg, collect_stats=True)
    assert len(stats) == rounds
    assert all(len(per_round) == 1 for per_round in stats)     # 1 device
    assert any(st.lb_invoked for per_round in stats for st in per_round)
    # replicated sync reports the all-reduce baseline volume per round
    v = g.num_vertices
    assert all(st.bytes_synced == v * 4
               for per_round in stats for st in per_round)
    ref = sssp(g, src, cfg)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref.labels))


def test_gluon_mirror_sync_single_device_parity():
    """sync='mirror' on a 1-device mesh: the ring is empty, but the
    owned-state loop, dirty mask, and master assembly all run."""
    g = G.rmat(9, 8, seed=3)
    src = G.highest_out_degree_vertex(g)
    mesh = gluon.device_mesh(1)
    sg, meta = partition(g, 1, "oec")
    cfg = BalancerConfig(strategy="alb", threshold=64)
    ref = sssp(g, src, cfg)
    labels, rounds, _, stats = gluon.sssp_distributed(
        sg, mesh, src, cfg, collect_stats=True, sync="mirror", meta=meta)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref.labels))
    # no peers -> no mirror traffic at all
    assert all(st.bytes_synced == 0
               for per_round in stats for st in per_round)


def test_gluon_kcore_distributed_single_device():
    from repro.core.apps import kcore
    g = G.symmetrized(G.rmat(9, 8, seed=3))
    mesh = gluon.device_mesh(1)
    sg, meta = partition(g, 1, "oec")
    cfg = BalancerConfig(strategy="alb", threshold=64)
    ref = kcore(g, 8, cfg)
    for sync in ["replicated", "mirror"]:
        labels, rounds, _ = gluon.kcore_distributed(
            sg, mesh, 8, cfg, sync=sync, meta=meta)
        np.testing.assert_array_equal(np.asarray(labels),
                                      np.asarray(ref.labels))


# ---------------- multi-device (subprocess, slow) -------------------------

MULTIDEV_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graph as G
from repro.core.partition import partition
from repro.core import gluon
from repro.core.balancer import BalancerConfig
from repro.core.apps import sssp, cc, pagerank

assert len(jax.devices()) == 4, jax.devices()
g = G.rmat(9, 8, seed=5)
src = G.highest_out_degree_vertex(g)
mesh = gluon.device_mesh(4)
sg, meta = partition(g, 4, "oec")
cfg = BalancerConfig(strategy="alb", threshold=64, use_pallas=True)
ref = sssp(g, src, BalancerConfig(strategy="alb", threshold=64))
labels, rounds, secs, stats = gluon.sssp_distributed(
    sg, mesh, src, cfg, collect_stats=True)
assert np.array_equal(np.asarray(labels), np.asarray(ref.labels))
assert all(len(per_round) == 4 for per_round in stats)
# per-device adaptivity: at least one round where some device fired the
# LB executor and some device skipped it would show as mixed flags; at
# minimum the flags must be well-formed booleans and edge counts consistent
for per_round in stats:
    for st in per_round:
        assert st.edges_lb == st.tile_loads_lb.sum()
# pallas kernels inside shard_map under the mirror substrate too
mlabels, _, _ = gluon.sssp_distributed(sg, mesh, src, cfg,
                                       sync="mirror", meta=meta)
assert np.array_equal(np.asarray(mlabels), np.asarray(ref.labels))
rg = G.reverse_graph(g)
srg, rmeta = partition(rg, 4, "oec")
rank, _, _ = gluon.pagerank_distributed(
    srg, mesh, g.out_degrees(), cfg=cfg, max_rounds=10, tol=0.0)
pref = pagerank(g, max_rounds=10, tol=0.0)
assert np.allclose(np.asarray(rank), np.asarray(pref.labels), atol=1e-6)
print("SPMD_PALLAS_OK")
"""


@pytest.mark.slow
def test_multidevice_pallas_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SPMD_PALLAS_OK" in out.stdout


# ---------------- fused host-round decision transfer ----------------------

def test_host_round_counts_layout():
    """relax's per-round host decisions come from ONE fused int32 vector
    (one device->host transfer) whose entries match the individual
    reductions it replaced."""
    from repro.core.balancer import _host_round_counts
    g = G.rmat(9, 8, seed=3)
    dist, frontier = _sssp_round_inputs(g)
    cfg = BalancerConfig(strategy="alb", threshold=64)
    cnt, union = _host_round_counts(g, frontier, cfg)
    cnt = np.asarray(cnt)
    np.testing.assert_array_equal(np.asarray(union), np.asarray(frontier))
    plan = make_plan(cfg)
    assert cnt.shape == (1 + 3 * len(plan.bins) + 2,)
    deg = np.asarray(g.row_ptr[1:]) - np.asarray(g.row_ptr[:-1])
    f = np.asarray(frontier)
    assert cnt[0] == f.sum()
    k = 1
    for spec in plan.bins:
        m = np.asarray(spec.mask(jnp.asarray(deg), jnp.asarray(f)))
        assert cnt[k] == m.sum()
        assert cnt[k + 1] == (deg * m).max(initial=0)
        assert cnt[k + 2] == (deg * m).sum()
        k += 3
    hm = f & (deg >= cfg.threshold)
    assert cnt[k] == hm.sum() and cnt[k + 1] == (deg * hm).sum()


# ---------------- planner unit coverage -----------------------------------

def test_plan_shapes():
    alb = make_plan(BalancerConfig(strategy="alb", threshold=64))
    assert alb.lb == "huge" and len(alb.bins) == 3
    assert all(b.static_passes() is not None for b in alb.bins)
    twc = make_plan(BalancerConfig(strategy="twc"))
    assert twc.lb == "none" and twc.bins[-1].static_passes() is None
    assert make_plan(BalancerConfig(strategy="edge_lb")).lb == "all"
    vx = make_plan(BalancerConfig(strategy="vertex"))
    assert vx.lb == "none" and len(vx.bins) == 1
