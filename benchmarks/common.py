"""Shared benchmark plumbing: inputs, timing, CSV emission."""
from __future__ import annotations

import time

import numpy as np

from repro.core import graph as G


def bench_graphs(scale: int = 14, seed: int = 1):
    """Structural analogues of the paper's input classes (Table 1):
    power-law (rmat*), flat road network (road-USA), moderate-skew
    social (orkut -> uniform high-degree)."""
    return {
        "rmat": G.rmat(scale, 16, seed=seed),
        "road": G.road_grid(1 << (scale // 2 + 1), seed=seed),
        "uniform": G.uniform_random(1 << scale, 16, seed=seed),
    }


def symmetrized(g):
    return G.symmetrized(g)


def pick_sources(g, n: int, seed: int = 0) -> list:
    """n distinct sources with out-degree > 0: the highest-degree hub
    (the paper's source pick) plus random reachable starts — the mixed
    traffic a query-serving deployment sees.  Shared by the qps and
    serve harnesses so both measure the same workload shape."""
    deg = np.asarray(g.out_degrees())
    cand = np.flatnonzero(deg > 0)
    rng = np.random.default_rng(seed)
    hub = int(np.argmax(deg))
    picks = [hub]
    for v in rng.permutation(cand):
        if len(picks) == n:
            break
        if int(v) != hub:
            picks.append(int(v))
    return picks


def timed(fn, repeats: int = 3):
    """median-of-N wall clock (first call includes jit; we warm once)."""
    fn()                                     # warmup (compilation)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.0f},{derived}", flush=True)
