"""Fused device-resident traversal loop (DESIGN.md section 11).

Four properties under test:

* **bitwise parity** — ``mode="fused"`` labels, round counts, and
  per-round stats (frontier size/edges + resolved direction) equal
  host mode across strategy × backend × direction × batch cells (the
  exhaustive matrix runs under ``-m slow``; a representative slice
  stays in tier 1);
* **zero host syncs** — structurally: the host-path round entries are
  poisoned under the spy and the ``host_transfers`` counter must not
  move between the fused dispatch and the final fetch;
* **merge-path mapping** — the co-ranked tile search against a numpy
  ``searchsorted`` oracle at the tile boundaries (empty frontier,
  one huge vertex, ragged tail tile, zero-degree runs);
* **bounded jit caches** — the ``_gather_bin`` per-(cap, fcap, v)
  bucket cache evicts LRU at its cap instead of growing without bound.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import graph as G
from repro.core import balancer
from repro.core.balancer import (BalancerConfig, host_transfer_count,
                                 run_fused)
from repro.core.apps import drivers as drv
from repro.kernels import merge_path as mp

STRATS = ["vertex", "twc", "edge_lb", "alb"]
BACKENDS = [None, "pallas", "merge_path"]
DIRS = ["push", "pull", "adaptive"]


@pytest.fixture(scope="module")
def graph():
    return G.uniform_random(200, avg_degree=6, seed=3)


@pytest.fixture(scope="module")
def sym_graph(graph):
    return G.symmetrized(graph)


def _assert_fused_matches_host(run, check_stats=True):
    """run(mode) -> AppResult; asserts bitwise parity + zero fused
    transfers + per-round stats/direction-trace agreement."""
    rh = run("host")
    t0 = host_transfer_count()
    rf = run("fused")
    assert rf.host_transfers == 0
    # the AppResult accounting and the module counter must agree: the
    # fused traversal touched the host zero times
    assert host_transfer_count() - t0 == 0
    np.testing.assert_array_equal(np.asarray(rh.labels),
                                  np.asarray(rf.labels))
    assert rh.rounds == rf.rounds
    assert rh.host_transfers >= rh.rounds   # >= 1 blocking sync/round
    if rh.stats is not None or rf.stats is not None:
        assert check_stats
        assert len(rh.stats) == len(rf.stats)
        for a, b in zip(rh.stats, rf.stats):
            assert (a.frontier_size, a.frontier_edges, a.direction) == \
                   (b.frontier_size, b.frontier_edges, b.direction)
            assert b.host_transfers == 0


# ---------------------------------------------------------------------------
# parity: representative tier-1 slice
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("strategy", STRATS)
def test_sssp_fused_parity_adaptive(graph, strategy, backend):
    cfg = BalancerConfig(strategy=strategy, threshold=64,
                         direction="adaptive", backend=backend)
    _assert_fused_matches_host(
        lambda mode: drv.sssp(graph, 0, cfg=cfg, mode=mode,
                              collect_stats=True))


@pytest.mark.parametrize("direction", ["push", "pull"])
@pytest.mark.parametrize("backend", [None, "merge_path"])
def test_bfs_fused_parity_directions(graph, direction, backend):
    cfg = BalancerConfig(strategy="alb", threshold=64,
                         direction=direction, backend=backend)
    _assert_fused_matches_host(
        lambda mode: drv.bfs(graph, 0, cfg=cfg, mode=mode,
                             collect_stats=True))


@pytest.mark.parametrize("app,sources", [("bfs", [0, 5, 9, 17]),
                                         ("sssp", [0, 5, 99, 150])])
def test_batch_fused_parity(graph, app, sources):
    cfg = BalancerConfig(strategy="alb", threshold=64,
                         direction="adaptive", backend="merge_path")
    batch = drv.bfs_batch if app == "bfs" else drv.sssp_batch
    _assert_fused_matches_host(
        lambda mode: batch(graph, sources, cfg=cfg, mode=mode,
                           collect_stats=True))


def test_cc_fused_parity(sym_graph):
    cfg = BalancerConfig(strategy="alb", threshold=64,
                         direction="adaptive")
    _assert_fused_matches_host(
        lambda mode: drv.cc(sym_graph, cfg=cfg, mode=mode,
                            collect_stats=True))


def test_kcore_fused_parity(sym_graph):
    cfg = BalancerConfig(strategy="alb", threshold=64)
    _assert_fused_matches_host(
        lambda mode: drv.kcore(sym_graph, 3, cfg=cfg, mode=mode,
                               collect_stats=True))


def test_pagerank_fused_parity(graph):
    cfg = BalancerConfig(strategy="alb", threshold=64)
    rh = drv.pagerank(graph, cfg=cfg, mode="host")
    rf = drv.pagerank(graph, cfg=cfg, mode="fused")
    # f32 power iteration: bitwise, not just allclose — both modes run
    # the identical jitted round arithmetic (drivers._pr_round_math)
    np.testing.assert_array_equal(np.asarray(rh.labels),
                                  np.asarray(rf.labels))
    assert rh.rounds == rf.rounds
    assert rf.host_transfers == 0 and rh.host_transfers >= rh.rounds


def test_fused_rejects_non_min_combine(graph):
    with pytest.raises(ValueError, match="min-combine"):
        run_fused(graph, jnp.zeros((graph.num_vertices,), jnp.float32),
                  jnp.ones((graph.num_vertices,), bool),
                  BalancerConfig(), drv.ops.PR_PULL)


# ---------------------------------------------------------------------------
# parity: exhaustive matrix (slow suite; also gated by
# benchmarks/fig_fused.py --smoke)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("strategy", STRATS)
def test_fused_full_matrix(strategy, backend):
    g = G.road_grid(8, seed=0)
    gs = G.symmetrized(g)
    for direction in DIRS:
        cfg = BalancerConfig(strategy=strategy, threshold=16,
                             direction=direction, backend=backend)
        _assert_fused_matches_host(
            lambda mode: drv.sssp(g, 0, cfg=cfg, mode=mode,
                                  collect_stats=True))
        _assert_fused_matches_host(
            lambda mode: drv.bfs(g, 0, cfg=cfg, mode=mode,
                                 collect_stats=True))
        _assert_fused_matches_host(
            lambda mode: drv.cc(gs, cfg=cfg, mode=mode,
                                collect_stats=True))
        _assert_fused_matches_host(
            lambda mode: drv.sssp_batch(g, [0, 7, 21, 63], cfg=cfg,
                                        mode=mode, collect_stats=True))
        _assert_fused_matches_host(
            lambda mode: drv.bfs_batch(g, [0, 7, 21, 63], cfg=cfg,
                                       mode=mode, collect_stats=True))
    # kcore / pagerank are push-only drivers
    cfg = BalancerConfig(strategy=strategy, threshold=16,
                         backend=backend)
    _assert_fused_matches_host(
        lambda mode: drv.kcore(gs, 2, cfg=cfg, mode=mode,
                               collect_stats=True))
    rh = drv.pagerank(g, cfg=cfg, mode="host")
    rf = drv.pagerank(g, cfg=cfg, mode="fused")
    np.testing.assert_array_equal(np.asarray(rh.labels),
                                  np.asarray(rf.labels))
    assert rh.rounds == rf.rounds and rf.host_transfers == 0


# ---------------------------------------------------------------------------
# zero-sync: structural spy
# ---------------------------------------------------------------------------

def _poison(name):
    def fn(*a, **k):
        raise AssertionError(
            f"fused mode reached the host-path round entry {name}")
    return fn


def test_fused_mode_never_touches_host_round_path(graph, monkeypatch):
    """Between dispatch and the final fetch a fused traversal must
    perform ZERO blocking device->host syncs: the host-path round
    entries are poisoned (any call fails loudly) and the module-level
    transfer counter must not move."""
    monkeypatch.setattr(drv, "relax", _poison("relax"))
    monkeypatch.setattr(drv, "relax_spmd_directed",
                        _poison("relax_spmd_directed"))
    monkeypatch.setattr(balancer, "_note_host_transfer",
                        _poison("_note_host_transfer"))
    monkeypatch.setattr(drv, "_note_host_transfer",
                        _poison("_note_host_transfer"))
    cfg = BalancerConfig(strategy="alb", threshold=64,
                         direction="adaptive")
    out = drv.bfs(graph, 0, cfg=cfg, mode="fused", collect_stats=True)
    assert out.host_transfers == 0
    ref = drv.ops  # sanity: the traversal really ran
    assert out.rounds > 1 and len(out.stats) == out.rounds
    del ref


def test_host_mode_counts_transfers(graph):
    cfg = BalancerConfig(strategy="alb", threshold=64)
    t0 = host_transfer_count()
    out = drv.bfs(graph, 0, cfg=cfg, mode="host")
    assert out.host_transfers == host_transfer_count() - t0
    assert out.host_transfers >= out.rounds


# ---------------------------------------------------------------------------
# merge-path mapping: tile boundaries vs searchsorted oracle
# ---------------------------------------------------------------------------

def _oracle(start_e, row_start, total, n_ids):
    ids = np.arange(n_ids)
    mask = ids < total
    j = np.clip(np.searchsorted(start_e, ids, side="right") - 1,
                0, len(start_e) - 1)
    ge = np.where(mask, row_start[j] + ids - start_e[j], 0)
    return ge, np.where(mask, j, j), mask


def _check_merge_path(deg, row_start, total, tile_edges=256):
    deg = np.asarray(deg, np.int32)
    start_e = np.cumsum(deg) - deg
    ecap = int(max(total, 1))
    ge, j, mask = mp.merge_path_map(
        jnp.asarray(start_e, jnp.int32),
        jnp.asarray(row_start, jnp.int32),
        jnp.int32(total), ecap, tile_edges=tile_edges)
    ge, j, mask = (np.asarray(x) for x in (ge, j, mask))
    oge, oj, omask = _oracle(start_e, np.asarray(row_start), total,
                             len(mask))
    np.testing.assert_array_equal(mask, omask)
    np.testing.assert_array_equal(ge[mask], oge[omask])
    np.testing.assert_array_equal(j[mask], oj[omask])


def test_merge_path_empty_frontier():
    # total = 0: every id masked, no memory traffic implied
    _check_merge_path([0, 0, 0, 0], [0, 0, 0, 0], total=0)


def test_merge_path_single_huge_vertex():
    # H = 1, degree >> tile_edges: many tiles co-rank into one slot
    _check_merge_path([5000], [17], total=5000, tile_edges=256)


def test_merge_path_ragged_tail_tile():
    # E not divisible by the tile size: the tail tile is partial
    deg = [100, 900, 1, 499, 1500]
    row_start = [0, 100, 1000, 1001, 1500]
    _check_merge_path(deg, row_start, total=3000, tile_edges=1024)


def test_merge_path_zero_degree_runs():
    # runs of zero-degree slots share a prefix value: edges must land
    # on the LAST slot with start_e <= id (searchsorted-right rule)
    deg = [2, 0, 0, 3, 0, 5, 0]
    row_start = [0, 2, 2, 2, 5, 5, 10]
    _check_merge_path(deg, row_start, total=10, tile_edges=128)


def test_merge_path_executor_has_no_bins(graph):
    cfg = BalancerConfig(strategy="alb", backend="merge_path")
    plan = balancer.effective_plan(cfg)
    assert plan.bins == () and plan.lb == "all"
    from repro.kernels import ops as kops
    with pytest.raises(RuntimeError, match="no degree bins"):
        kops.merge_path_no_bins()


# ---------------------------------------------------------------------------
# bounded _gather_bin cache
# ---------------------------------------------------------------------------

def test_gather_bin_cache_lru_eviction(monkeypatch):
    monkeypatch.setattr(balancer, "_GATHER_BIN_CACHE_CAP", 3)
    cache = balancer._GATHER_BIN_CACHE
    cache.clear()
    mask = jnp.zeros((8,), bool).at[2].set(True)
    fidx = jnp.arange(8, dtype=jnp.int32)
    deg = jnp.ones((8,), jnp.int32)
    row = jnp.arange(8, dtype=jnp.int32)

    for cap in (2, 4, 8):
        balancer._gather_bin(mask, fidx, deg, row, cap, 8, 8)
    assert list(cache) == [(2, 8, 8), (4, 8, 8), (8, 8, 8)]

    balancer._gather_bin(mask, fidx, deg, row, 2, 8, 8)   # hit: MRU
    assert list(cache) == [(4, 8, 8), (8, 8, 8), (2, 8, 8)]

    balancer._gather_bin(mask, fidx, deg, row, 4, 4, 8)   # miss at cap
    assert len(cache) == 3
    assert (4, 8, 8) not in cache          # LRU evicted
    assert list(cache)[-1] == (4, 4, 8)

    # evicted bucket still works when re-requested (recompiles)
    out = balancer._gather_bin(mask, fidx, deg, row, 4, 8, 8)
    assert len(cache) == 3
    assert np.asarray(out[0])[0] == 2      # vidx of the one set slot


# ---------------------------------------------------------------------------
# serving + distributed fused
# ---------------------------------------------------------------------------

def test_serve_fused_bitwise_and_fewer_transfers(graph):
    from repro.serve import QueryService
    cfg = BalancerConfig(strategy="alb", direction="adaptive",
                         threshold=64)
    results, transfers = {}, {}
    for mode in ("host", "fused"):
        svc = QueryService(num_slots=4, cfg=cfg, mode=mode,
                           cache_capacity=0)
        svc.register_graph("g", graph)
        qids = [svc.submit("g", "bfs", s) for s in (0, 11, 23, 41, 77)]
        qids += [svc.submit("g", "sssp", s) for s in (0, 99)]
        st = svc.run()
        results[mode] = [np.asarray(svc.poll(q).result) for q in qids]
        transfers[mode] = st.host_transfers
        assert st.host_transfers > 0
        assert st.summary()["host_transfers"] == st.host_transfers
    for a, b in zip(results["host"], results["fused"]):
        np.testing.assert_array_equal(a, b)
    # fused amortizes the per-round observation over whole chunks
    assert transfers["fused"] < transfers["host"]


_DIST_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graph as G
from repro.core.partition import partition
from repro.core import gluon
from repro.core.balancer import BalancerConfig, host_transfer_count

assert len(jax.devices()) == 4, jax.devices()
g = G.rmat(8, 8, seed=5)
src = G.highest_out_degree_vertex(g)
cfg = BalancerConfig(strategy="alb", threshold=64)
mesh = gluon.device_mesh(4)
sg, meta = partition(g, 4, "oec")
for sync in ["replicated", "mirror"]:
    lh, rh, _ = gluon.sssp_distributed(sg, mesh, src, cfg, sync=sync,
                                       meta=meta, mode="host")
    t0 = host_transfer_count()
    lf, rf, _ = gluon.sssp_distributed(sg, mesh, src, cfg, sync=sync,
                                       meta=meta, mode="fused")
    assert host_transfer_count() - t0 == 0, sync
    assert rh == rf, (sync, rh, rf)
    assert np.array_equal(np.asarray(lh), np.asarray(lf)), sync

rg = G.reverse_graph(g)
srg, rmeta = partition(rg, 4, "oec")
outdeg = jnp.asarray(np.diff(np.asarray(g.row_ptr)))
for sync in ["replicated", "mirror"]:
    kh, rh, _ = gluon.pagerank_distributed(
        srg, mesh, outdeg, cfg=cfg, sync=sync, meta=rmeta,
        mode="host", max_rounds=20)
    kf, rf, _ = gluon.pagerank_distributed(
        srg, mesh, outdeg, cfg=cfg, sync=sync, meta=rmeta,
        mode="fused", max_rounds=20)
    assert rh == rf, (sync, rh, rf)
    assert np.array_equal(np.asarray(kh), np.asarray(kf)), sync
print("DIST-FUSED-OK")
"""


@pytest.mark.slow
def test_distributed_fused_parity_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _DIST_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DIST-FUSED-OK" in out.stdout
