"""CSR graph container + synthetic graph generators.

The paper evaluates on RMAT power-law graphs, social networks (orkut,
twitter40), and road networks (road-USA).  We generate structurally
equivalent synthetic inputs: RMAT (power-law out-degree), a 2-D grid
"road" network (constant low degree, huge diameter), and a uniform
random graph (Erdos-Renyi-ish).

The device-resident representation is CSR (row_ptr, col_idx, edge_w),
exactly as in the paper (Section 4.1: "like most systems in this space,
our system uses a CSR representation of the graph to save space").
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel "infinity" for int32 distance labels.  We avoid INT32_MAX so
# that INF + weight does not wrap around.
INF = np.int32(1 << 30)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Device-resident CSR graph.

    row_ptr : int32[V+1]   prefix of out-degrees
    col_idx : int32[E]     destination vertex of each edge
    edge_w  : int32[E]     edge weights (all-ones for unweighted apps)
    """

    row_ptr: jax.Array
    col_idx: jax.Array
    edge_w: jax.Array

    # ---- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        return (self.row_ptr, self.col_idx, self.edge_w), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # ---- basic properties ------------------------------------------------
    @property
    def num_vertices(self) -> int:
        return self.row_ptr.shape[0] - 1

    @property
    def num_edges(self) -> int:
        return self.col_idx.shape[0]

    @property
    def version(self) -> int:
        """Monotonically increasing topology version (DESIGN.md
        section 10).  Every derived structure memoized on the Graph —
        the reverse CSR below, the pull enumerations in
        ``repro.core.balancer``, the host edge map in
        ``repro.core.streaming`` — keys its cache entry on this value,
        so a version bump (``bump_version``, issued by the streaming
        update path) atomically invalidates all of them.  Stored
        outside the pytree: a traced Graph never sees it and version
        bumps never change jit cache keys."""
        return self.__dict__.get("_version", 0)

    def bump_version(self) -> None:
        """Advance :attr:`version` after an in-place topology change
        (``repro.core.streaming.apply_updates(..., in_place=True)``).
        Must be called by ANY code that swaps this object's CSR arrays
        underneath existing references — the memoized ``reverse()`` /
        pull-enumeration caches check the version on every lookup, so
        the bump is what keeps them from serving the old topology."""
        object.__setattr__(self, "_version", self.version + 1)

    def out_degrees(self) -> jax.Array:
        return self.row_ptr[1:] - self.row_ptr[:-1]

    def max_out_degree(self) -> int:
        return int(jnp.max(self.out_degrees()))  # repro: allow[host-sync] -- one-time planner-setup scalar, not per-round

    def reverse(self) -> "Graph":
        """Memoized reverse view (:func:`reverse_graph`): the CSC of
        this graph stored as a CSR, i.e. in-edges become out-edges.

        Pull-direction rounds (DESIGN.md section 9) traverse it every
        round, so the host-side transpose is built once per Graph
        object and cached (the cache is an ordinary attribute, not a
        pytree leaf — a jit-traced Graph never sees it).

        The cache entry is keyed on :attr:`version`: an in-place
        topology change (DESIGN.md section 10) bumps the version, so a
        stale transpose can never be served — without the key, a pull
        round after a mutation would silently traverse the old
        topology."""
        cached = self.__dict__.get("_reverse_cache")
        if cached is None or cached[0] != self.version:
            cached = (self.version, reverse_graph(self))
            object.__setattr__(self, "_reverse_cache", cached)
        return cached[1]


# ---------------------------------------------------------------------------
# Construction helpers (host side, numpy).
# ---------------------------------------------------------------------------

def from_edge_list(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                   weights: np.ndarray | None = None,
                   dedup: bool = True) -> Graph:
    """Build a CSR Graph from a COO edge list (host-side).

    ``dedup=True`` collapses parallel edges deterministically: each
    (src, dst) pair keeps the **minimum** weight among its duplicates
    (for unweighted input all duplicates are unit weight, so any
    representative is equivalent).  Min is the right collapse for the
    shortest-path family this repo propagates — a parallel edge bundle
    relaxes exactly like its cheapest member — and, unlike the previous
    keep-first-occurrence rule, does not depend on the input edge
    order.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if dedup and len(src):
        key = src * np.int64(num_vertices) + dst
        if weights is None:
            _, keep = np.unique(key, return_index=True)
            src, dst = src[keep], dst[keep]
        else:
            weights = np.asarray(weights)
            # sort by (key, weight): the first edge of each key run is
            # its minimum-weight duplicate
            by_w = np.lexsort((weights, key))
            key, src, dst, weights = (key[by_w], src[by_w], dst[by_w],
                                      weights[by_w])
            keep = np.concatenate([[True], key[1:] != key[:-1]])
            src, dst, weights = src[keep], dst[keep], weights[keep]
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    if weights is None:
        weights = np.ones(len(src), dtype=np.int32)
    else:
        weights = np.asarray(weights, dtype=np.int32)[order]
    counts = np.bincount(src, minlength=num_vertices).astype(np.int32)
    row_ptr = np.zeros(num_vertices + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    return Graph(
        row_ptr=jnp.asarray(row_ptr),
        col_idx=jnp.asarray(dst.astype(np.int32)),
        edge_w=jnp.asarray(weights),
    )


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         weighted: bool = True, max_weight: int = 100) -> Graph:
    """RMAT generator (Chakrabarti et al.), the paper's power-law inputs.

    Produces ~2**scale vertices, edge_factor * 2**scale directed edges
    with a power-law out-degree distribution (a-heavy corner => vertex 0
    region accumulates huge out-degree, mirroring rmat23's 35M max Dout).
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        # pick quadrant: 0=a 1=b 2=c 3=d
        quad = np.select(
            [r < a, r < ab, r < abc], [0, 1, 2], default=3)
        src = (src << 1) | (quad >= 2)
        dst = (dst << 1) | (quad & 1)
    w = rng.integers(1, max_weight + 1, size=m) if weighted else None
    return from_edge_list(src, dst, n, weights=w)


def road_grid(side: int, seed: int = 0, weighted: bool = True,
              max_weight: int = 100) -> Graph:
    """2-D grid graph: constant degree <= 4, diameter 2*side.

    Structural stand-in for road-USA (max degree 9, diameter 6261).
    """
    rng = np.random.default_rng(seed)
    n = side * side
    vs = np.arange(n).reshape(side, side)
    srcs, dsts = [], []
    # bidirectional horizontal + vertical edges
    srcs += [vs[:, :-1].ravel(), vs[:, 1:].ravel(),
             vs[:-1, :].ravel(), vs[1:, :].ravel()]
    dsts += [vs[:, 1:].ravel(), vs[:, :-1].ravel(),
             vs[1:, :].ravel(), vs[:-1, :].ravel()]
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    w = rng.integers(1, max_weight + 1, size=len(src)) if weighted else None
    return from_edge_list(src, dst, n, weights=w)


def uniform_random(num_vertices: int, avg_degree: int = 8, seed: int = 0,
                   weighted: bool = True, max_weight: int = 100) -> Graph:
    """Uniform random digraph (no skew) — the balanced control input."""
    rng = np.random.default_rng(seed)
    m = num_vertices * avg_degree
    src = rng.integers(0, num_vertices, size=m)
    dst = rng.integers(0, num_vertices, size=m)
    w = rng.integers(1, max_weight + 1, size=m) if weighted else None
    return from_edge_list(src, dst, num_vertices, weights=w)


def to_coo(g: Graph) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side COO expansion ``(src, dst, weight)`` of a CSR graph.

    The one place the ``row_ptr``-to-source expansion lives; the
    partitioner (which slices edges by owner), ``reverse_graph`` and the
    benchmark symmetrizer all consume it.

    Only the ``row_ptr[-1]`` edges owned by some vertex are expanded:
    a padded graph (``pad_graph``, or the streaming shapes of
    DESIGN.md section 10) stores sentinel-targeting filler beyond that
    point, which belongs to no vertex and is not part of the semantic
    edge set.
    """
    row_ptr = np.asarray(g.row_ptr).astype(np.int64)
    e_real = int(row_ptr[-1])
    dst = np.asarray(g.col_idx)[:e_real].astype(np.int64)
    w = np.asarray(g.edge_w)[:e_real]
    src = np.repeat(np.arange(g.num_vertices, dtype=np.int64),
                    row_ptr[1:] - row_ptr[:-1])
    return src, dst, w


def reverse_graph(g: Graph) -> Graph:
    """CSC view (incoming edges) as a CSR graph — used by pull operators.

    Shape-preserving: when ``g`` carries edge padding (its ``col_idx``
    is longer than ``row_ptr[-1]``), the transpose is padded back to
    the same edge capacity with the same sentinel-targeting filler, so
    pull rounds over a streaming graph (DESIGN.md section 10) see
    fixed shapes across versions, exactly like push rounds over the
    forward CSR."""
    src, dst, w = to_coo(g)
    rg = from_edge_list(dst, src, g.num_vertices, weights=w,
                        dedup=False)
    ecap, e = g.num_edges, rg.num_edges
    if ecap > e:
        vp = g.num_vertices
        rg = Graph(
            row_ptr=rg.row_ptr,
            col_idx=jnp.concatenate(
                [rg.col_idx, jnp.full((ecap - e,), vp - 1, jnp.int32)]),
            edge_w=jnp.concatenate(
                [rg.edge_w, jnp.full((ecap - e,), INF, jnp.int32)]))
    if "_v_real" in g.__dict__:
        object.__setattr__(rg, "_v_real", g.__dict__["_v_real"])
    return rg


def symmetrized(g: Graph) -> Graph:
    """Undirected view: every edge plus its reverse (deduplicated) —
    what cc and kcore expect.

    Weights are preserved on both directions; when the input already
    has both (u, v) and (v, u) with different weights, dedup keeps the
    minimum, so ``w(u, v) == w(v, u)`` holds in the result and weighted
    SSSP over a symmetrized graph relaxes real edge weights (it used to
    silently degrade to unit weights / BFS)."""
    src, dst, w = to_coo(g)
    return from_edge_list(np.concatenate([src, dst]),
                          np.concatenate([dst, src]), g.num_vertices,
                          weights=np.concatenate([w, w]))


def highest_out_degree_vertex(g: Graph) -> int:
    """Paper's bfs/sssp source for power-law graphs."""
    return int(jnp.argmax(g.out_degrees()))  # repro: allow[host-sync] -- one-time benchmark-setup source pick


# ---------------------------------------------------------------------------
# Padding: devices want power-of-two-ish aligned arrays.
# ---------------------------------------------------------------------------

def pad_graph(g: Graph, v_multiple: int = 8, e_multiple: int = 1024) -> Graph:
    """Pad V and E to multiples so Pallas BlockSpecs tile cleanly.

    Padded vertices have degree 0.  Padded edges must target a *padded*
    vertex: the INF-ish weight only protects weight-respecting
    operators, and an executor that enumerates edge ids over the padded
    span would corrupt a real vertex's label under weight-ignoring
    operators (cc, kcore) if padding aimed at one.  So whenever edge
    padding exists, vertex padding is forced to exist too (``vp > v``)
    and every padded edge points at the padded vertex ``vp - 1`` —
    degree 0, label never read.
    """
    v, e = g.num_vertices, g.num_edges
    vp = -(-v // v_multiple) * v_multiple
    ep = -(-e // e_multiple) * e_multiple
    if ep > e and vp == v:
        vp = v + v_multiple           # guarantee a padded-edge target
    if vp == v and ep == e:
        return g
    row_ptr = jnp.concatenate(
        [g.row_ptr, jnp.full((vp - v,), g.row_ptr[-1], dtype=jnp.int32)])
    col_idx = jnp.concatenate(
        [g.col_idx, jnp.full((ep - e,), vp - 1, dtype=jnp.int32)])
    edge_w = jnp.concatenate(
        [g.edge_w, jnp.full((ep - e,), INF, dtype=jnp.int32)])
    return Graph(row_ptr=row_ptr, col_idx=col_idx, edge_w=edge_w)
