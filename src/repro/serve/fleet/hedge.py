"""SLO-conditional hedging of stragglers (DESIGN.md section 13).

Every served result is deterministic and bitwise-reproducible (the
engine's parity invariant), so a hedge is FREE to race its primary:
whichever finisher lands first is published and the other is
cancelled — the answers could not have differed.  What hedging must
still control is overhead, so it is conditional three ways:

* **SLO-conditional** — a query becomes hedgeable only after
  ``hedge_after`` fleet steps in system (the threshold the feedback
  controller lowers under p95 pressure and restores when calm);
* **bounded per query** — at most ``max_hedges`` hedge copies;
* **capacity-conditional** — a hedge launches only if its target
  would stay under the bounded-load ceiling, so hedge traffic can
  never stampede an already-loaded fleet (and every EXECUTED
  assignment, hedge or not, respects the ceiling — the structural
  gate ``trace.ceiling_violations`` checks).

The cancel-on-first-finish half lives in the fleet engine: the winner
is published through the ``publish.freeze`` choke point exactly once,
the loser is cancelled via :meth:`QueryService.cancel` (or, if it
finished in the same step, simply dropped — never double-published).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """Static hedging knobs (``hedge_after`` itself is adaptive and
    lives on the router's feedback controller)."""
    enabled: bool = True
    max_hedges: int = 1             # hedge copies per query, ever


def hedgeable(rec, step: int, hedge_after: int,
              policy: HedgePolicy) -> bool:
    """Whether a fleet query record is eligible for (another) hedge at
    ``step``: hedging on, still in flight, over the SLO age threshold,
    under its per-query hedge budget, and with at least one replica
    not already holding it."""
    return (policy.enabled
            and rec.status == "running"
            and step - rec.submit_step >= hedge_after
            and rec.hedges < policy.max_hedges)
