"""Direction-optimizing rounds (DESIGN.md section 9).

The invariants under test:

* **Parity matrix** — for every min-combine app (bfs/sssp/cc),
  ``direction="pull"`` and ``direction="adaptive"`` labels are bitwise
  equal to the existing push labels, across all 4 strategies, both
  round modes (host + spmd), and batch sizes B in {1, 4}.
* **Adaptivity is structural** — ``adaptive`` selects pull on a full
  frontier and push on a one-hot low-degree frontier, the per-round
  direction trace matches :func:`resolve_direction` replayed over the
  recorded counts, and RoundStats records the chosen direction.
  (Deterministic gates only — no wall clock.)
* **Validation** — flipping is defined only for push min-combine
  operators; add-combine (kcore) and natural-pull (pagerank) configs
  raise.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import operators as ops
from repro.core.apps import (bfs, sssp, cc, kcore, pagerank, bfs_batch,
                             sssp_batch)
from repro.core.balancer import BalancerConfig, resolve_direction

STRATS = ["vertex", "twc", "edge_lb", "alb"]
MODES = ["host", "spmd"]
DIRECTIONS = ["pull", "adaptive"]

GRAPH = G.rmat(8, 8, seed=3)
SGRAPH = G.symmetrized(GRAPH)
SRC = G.highest_out_degree_vertex(GRAPH)
SOURCES = [SRC, 1, 5, 9]


def _cfg(strategy: str, **kw) -> BalancerConfig:
    return BalancerConfig(strategy=strategy, threshold=64, **kw)


def _run(app: str, strategy: str, mode: str, direction):
    if app == "bfs":
        return bfs(GRAPH, SRC, _cfg(strategy), mode=mode,
                   direction=direction)
    if app == "sssp":
        return sssp(GRAPH, SRC, _cfg(strategy), mode=mode,
                    direction=direction)
    return cc(SGRAPH, _cfg(strategy), mode=mode, direction=direction)


@pytest.fixture(scope="module")
def push_labels():
    """Memoized push baselines per (app, strategy, mode)."""
    cache: dict = {}

    def get(app, strategy, mode):
        key = (app, strategy, mode)
        if key not in cache:
            cache[key] = np.asarray(_run(app, strategy, mode,
                                         "push").labels)
        return cache[key]

    return get


# ---------------------------------------------------------------------------
# parity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("direction", DIRECTIONS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("strategy", STRATS)
@pytest.mark.parametrize("app", ["bfs", "sssp", "cc"])
def test_direction_parity(app, strategy, mode, direction, push_labels):
    out = _run(app, strategy, mode, direction)
    np.testing.assert_array_equal(np.asarray(out.labels),
                                  push_labels(app, strategy, mode))


@pytest.mark.parametrize("b", [1, 4])
@pytest.mark.parametrize("direction", DIRECTIONS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("app", ["bfs", "sssp"])
def test_batched_direction_parity(app, mode, direction, b):
    driver = bfs_batch if app == "bfs" else sssp_batch
    srcs = SOURCES[:b]
    base = driver(GRAPH, srcs, _cfg("alb"), mode=mode)
    out = driver(GRAPH, srcs, _cfg("alb"), mode=mode,
                 direction=direction)
    np.testing.assert_array_equal(np.asarray(out.labels),
                                  np.asarray(base.labels))


def test_pull_pallas_matches_xla_push(push_labels):
    cfg = _cfg("alb", use_pallas=True)
    for mode in MODES:
        out = sssp(GRAPH, SRC, cfg, mode=mode, direction="pull")
        np.testing.assert_array_equal(np.asarray(out.labels),
                                      push_labels("sssp", "alb", mode))


def test_served_equals_standalone_under_adaptive():
    """A query served through the batched round loop with an adaptive
    config equals its standalone push run (the serving-layer parity
    criterion)."""
    out = sssp_batch(GRAPH, SOURCES, _cfg("alb"), direction="adaptive")
    for i, s in enumerate(SOURCES):
        ref = np.asarray(sssp(GRAPH, s, _cfg("alb")).labels)
        np.testing.assert_array_equal(np.asarray(out.labels[i]), ref)


# ---------------------------------------------------------------------------
# structural adaptivity gates (deterministic; no wall clock)
# ---------------------------------------------------------------------------

def test_resolve_direction_thresholds():
    cfg = BalancerConfig(direction="adaptive", pull_alpha=14,
                         pull_beta=24)
    # dense by vertices: n_f * beta >= V
    assert resolve_direction(cfg, 100, 0, 1000, 100000) == "pull"
    # dense by frontier out-edges: m_f * alpha >= E
    assert resolve_direction(cfg, 1, 999, 100000, 1000) == "pull"
    # sparse both ways
    assert resolve_direction(cfg, 1, 1, 1000, 10000) == "push"
    # fixed directions ignore the counts
    push_cfg = dataclasses.replace(cfg, direction="push")
    pull_cfg = dataclasses.replace(cfg, direction="pull")
    assert resolve_direction(push_cfg, 10**9, 10**9, 1, 1) == "push"
    assert resolve_direction(pull_cfg, 0, 0, 10, 10) == "pull"


@pytest.mark.parametrize("mode", MODES)
def test_adaptive_selects_pull_on_full_frontier(mode):
    """cc starts from a full frontier — round 1 must run as a pull."""
    out = cc(SGRAPH, _cfg("alb"), mode=mode, direction="adaptive",
             collect_stats=True)
    assert out.stats[0].frontier_size == SGRAPH.num_vertices
    assert out.stats[0].direction == "pull"


@pytest.mark.parametrize("mode", MODES)
def test_adaptive_selects_push_on_one_hot_frontier(mode):
    """A one-hot frontier at a low-degree vertex must run as a push."""
    g = G.road_grid(20, seed=0)             # V=400, degree <= 4
    out = bfs(g, 0, _cfg("alb"), mode=mode, direction="adaptive",
              collect_stats=True)
    assert out.stats[0].frontier_size == 1
    assert out.stats[0].direction == "push"


@pytest.mark.parametrize("mode", MODES)
def test_adaptive_trace_matches_threshold_rule(mode):
    """The recorded per-round direction is exactly the threshold rule
    replayed over the recorded per-round counts."""
    cfg = _cfg("alb", direction="adaptive")
    out = bfs(GRAPH, SRC, cfg, mode=mode, collect_stats=True)
    v, e = GRAPH.num_vertices, GRAPH.num_edges
    assert out.stats
    for st in out.stats:
        assert st.direction == resolve_direction(
            cfg, st.frontier_size, st.frontier_edges, v, e)


@pytest.mark.parametrize("mode", MODES)
def test_round_stats_record_fixed_directions(mode):
    pull = sssp(GRAPH, SRC, _cfg("alb"), mode=mode, direction="pull",
                collect_stats=True)
    assert pull.stats and all(st.direction == "pull"
                              for st in pull.stats)
    push = sssp(GRAPH, SRC, _cfg("alb"), mode=mode,
                collect_stats=True)
    assert push.stats and all(st.direction == "push"
                              for st in push.stats)


def test_adaptive_round_count_never_exceeds_push():
    """Each round relaxes the same candidate multiset in either
    direction, so adaptive cannot take more rounds than push-only."""
    push = bfs(GRAPH, SRC, _cfg("alb"))
    ad = bfs(GRAPH, SRC, _cfg("alb"), direction="adaptive")
    assert ad.rounds <= push.rounds


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_direction_requires_push_min_combine_operator():
    with pytest.raises(ValueError, match="min-combine"):
        kcore(SGRAPH, 4, _cfg("alb", direction="pull"))   # add-combine
    with pytest.raises(ValueError, match="min-combine"):
        pagerank(GRAPH, cfg=_cfg("alb", direction="adaptive"),
                 max_rounds=2)                            # natural pull


def test_distributed_runtime_rejects_direction_configs():
    """The distributed runtime is push-only (partitions cut along
    out-edges) — it must refuse direction-optimized configs instead of
    silently running push."""
    from repro.core import gluon
    with pytest.raises(ValueError, match="push-only"):
        gluon.run_distributed(None, None, ops.SSSP_RELAX, None, None,
                              cfg=_cfg("alb", direction="adaptive"))
    with pytest.raises(ValueError, match="push-only"):
        gluon.pagerank_distributed(None, None, None,
                                   cfg=_cfg("alb", direction="pull"))


def test_as_pull_memoized_twin():
    twin = ops.as_pull(ops.BFS_HOP)
    assert twin is ops.as_pull(ops.BFS_HOP)
    assert twin.direction == "pull"
    assert twin.combine == ops.BFS_HOP.combine
    with pytest.raises(ValueError):
        ops.as_pull(ops.PR_PULL)
    with pytest.raises(ValueError):
        ops.as_pull(ops.KCORE_DEC)


def test_bad_direction_config_rejected():
    with pytest.raises(AssertionError):
        BalancerConfig(direction="sideways")
