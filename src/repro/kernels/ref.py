"""Pure-jnp oracles for the Pallas kernels (per-kernel allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_lb_map_ref(start_e, row_start, hval, total_edges, n_enum,
                    *, tile_edges: int = 2048, distribution: str = "cyclic",
                    num_tiles: int = 64):
    """Oracle for edge_lb.edge_lb_map (same output contract)."""
    w_per = -(-n_enum // num_tiles)
    span = w_per * num_tiles            # exact bijection domain
    n_pad = -(-span // tile_edges) * tile_edges
    eid0 = jnp.arange(n_pad, dtype=jnp.int32)
    if distribution == "blocked":
        eid = (eid0 % num_tiles) * w_per + eid0 // num_tiles
    else:
        eid = eid0
    emask = (eid0 < span) & (eid < total_edges)
    eid_c = jnp.where(emask, eid, 0)
    j = jnp.searchsorted(start_e, eid_c, side="right") - 1
    j = jnp.clip(j, 0, start_e.shape[0] - 1)
    ge = jnp.where(emask, row_start[j] + (eid_c - start_e[j]), 0)
    return ge, j, hval[j], emask


def twc_bin_map_ref(vidx, deg, row_start, val, *, width: int,
                    chunk: int = 0, tile_v: int = 8,
                    sentinel: int = 1 << 30):
    """Oracle for twc_gather.twc_bin_map."""
    b = vidx.shape[0]
    bp = -(-b // tile_v) * tile_v
    pad = bp - b
    if pad:
        vidx = jnp.pad(vidx, (0, pad), constant_values=sentinel)
        deg = jnp.pad(deg, (0, pad))
        row_start = jnp.pad(row_start, (0, pad))
        val = jnp.pad(val, (0, pad))
    off = chunk * width + jnp.arange(width, dtype=jnp.int32)[None, :]
    emask = (off < deg[:, None]) & (vidx[:, None] < sentinel)
    ge = jnp.where(emask, row_start[:, None] + off, 0)
    anchor = jnp.broadcast_to(vidx[:, None], emask.shape)
    v = jnp.broadcast_to(val[:, None], emask.shape)
    return ge, anchor, v, emask


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Oracle for flash_attention: plain softmax attention (f32)."""
    import math
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, s, hkv, g, hd) / math.sqrt(hd)
    sc = jnp.einsum("bqkgd,bckd->bqkgc", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None, :, None, None, :], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def positions_in_expert_ref(flat_expert, num_experts: int):
    """Oracle for moe_dispatch: one-hot cumsum formulation."""
    onehot = jax.nn.one_hot(flat_expert, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(pos, flat_expert[:, None], axis=1)[:, 0]
