"""Master/mirror sync substrate acceptance (DESIGN.md section 6).

For every application and multiple partition policies, ``sync="mirror"``
must produce labels identical to ``sync="replicated"`` (ranks within
1e-6 for PageRank), while the dirty-tracked boundary exchange moves
strictly less data per round than the replicated all-reduce's
``V * itemsize * D`` baseline.

The in-process tests need >= 4 devices; they run natively in the CI
multi-device job (``XLA_FLAGS=--xla_force_host_platform_device_count=4``)
and skip under the plain single-device tier-1 run, where the
``slow``-marked subprocess test provides the same coverage on demand.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.core import graph as G
from repro.core.partition import partition
from repro.core import gluon
from repro.core.balancer import BalancerConfig

NDEV = 4
multidevice = pytest.mark.skipif(
    len(jax.devices()) < NDEV,
    reason=f"needs {NDEV} devices (CI sets "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={NDEV})")

CFG = BalancerConfig(strategy="alb", threshold=64)


def _total_bytes_per_round(stats):
    return [sum(st.bytes_synced for st in per_round) for per_round in stats]


@pytest.fixture(scope="module")
def rmat_graph():
    return G.rmat(9, 8, seed=5)


@multidevice
@pytest.mark.parametrize("policy", ["oec", "iec", "cvc"])
@pytest.mark.parametrize("app", ["sssp", "bfs"])
def test_single_source_apps_mirror_parity_and_volume(rmat_graph, app,
                                                     policy):
    g = rmat_graph
    src = G.highest_out_degree_vertex(g)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, policy)
    driver = gluon.sssp_distributed if app == "sssp" \
        else gluon.bfs_distributed
    ref, _, _ = driver(sg, mesh, src, CFG)
    labels, rounds, _, stats = driver(sg, mesh, src, CFG,
                                      collect_stats=True,
                                      sync="mirror", meta=meta)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref))
    # single-source frontier: every round's boundary exchange must beat
    # the replicated all-reduce's V * itemsize * D
    baseline = g.num_vertices * 4 * NDEV
    per_round = _total_bytes_per_round(stats)
    assert len(per_round) == rounds
    assert all(b < baseline for b in per_round), (per_round, baseline)


@multidevice
@pytest.mark.parametrize("policy", ["oec", "cvc"])
def test_cc_mirror_parity(rmat_graph, policy):
    g = G.symmetrized(rmat_graph)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, policy)
    ref, _, _ = gluon.cc_distributed(sg, mesh, CFG)
    labels, _, _, stats = gluon.cc_distributed(
        sg, mesh, CFG, collect_stats=True, sync="mirror", meta=meta)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref))
    # full-frontier start: still cheaper than replicated over the run
    baseline = g.num_vertices * 4 * NDEV
    per_round = _total_bytes_per_round(stats)
    assert sum(per_round) < baseline * len(per_round)


@multidevice
@pytest.mark.parametrize("policy", ["oec", "cvc"])
def test_kcore_mirror_parity(rmat_graph, policy):
    g = G.symmetrized(rmat_graph)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, policy)
    ref, _, _ = gluon.kcore_distributed(sg, mesh, 8, CFG)
    labels, _, _, stats = gluon.kcore_distributed(
        sg, mesh, 8, CFG, collect_stats=True, sync="mirror", meta=meta)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref))
    # logical volume = index word + [B=1] int32 payload per exchanged
    # vertex (8 bytes, not 4: the index side counts too)
    assert all(st.bytes_synced == st.mirrors_synced * (4 + 4)
               for per_round in stats for st in per_round)


@multidevice
def test_bytes_synced_counts_index_traffic(rmat_graph):
    """Accounting regression (failed before the wire-codec refactor):
    the exchange ships an int32 ``out_idx`` word alongside each dirty
    vertex's ``[B]`` payload in BOTH rings, so ``bytes_synced`` must be
    ``mirrors_synced * (INDEX_BYTES + B * itemsize)`` — the old count
    dropped the index side and reported payload bytes only."""
    g = rmat_graph
    src = G.highest_out_degree_vertex(g)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, "oec")
    # sssp exercises both rings (reduce-to-master AND broadcast)
    _, _, _, stats = gluon.sssp_distributed(
        sg, mesh, src, CFG, collect_stats=True, sync="mirror", meta=meta)
    assert any(st.mirrors_synced > 0
               for per_round in stats for st in per_round)
    for per_round in stats:
        for st in per_round:
            assert st.bytes_synced == st.mirrors_synced * (4 + 1 * 4)
            # identity wire (the default): post-encode == logical
            assert st.bytes_wire == st.bytes_synced
    # batched: the per-vertex payload scales by B, the index word not
    srcs = np.arange(8) * (g.num_vertices // 8)
    _, _, _, bstats = gluon.sssp_batch_distributed(
        sg, mesh, srcs, CFG, collect_stats=True, sync="mirror", meta=meta)
    for per_round in bstats:
        for st in per_round:
            assert st.bytes_synced == st.mirrors_synced * (4 + 8 * 4)


@multidevice
@pytest.mark.parametrize("policy", ["oec", "iec"])
def test_pagerank_mirror_parity(rmat_graph, policy):
    g = rmat_graph
    mesh = gluon.device_mesh(NDEV)
    srg, rmeta = partition(G.reverse_graph(g), NDEV, policy)
    ref, _, _ = gluon.pagerank_distributed(
        srg, mesh, g.out_degrees(), max_rounds=15, tol=0.0)
    rank, rounds, _, stats = gluon.pagerank_distributed(
        srg, mesh, g.out_degrees(), max_rounds=15, tol=0.0,
        collect_stats=True, sync="mirror", meta=rmeta)
    assert rounds == 15
    np.testing.assert_allclose(np.asarray(rank), np.asarray(ref), atol=1e-6)


@multidevice
def test_mirror_dirty_tracking_shrinks_with_frontier(rmat_graph):
    """As the sssp frontier collapses, so must the exchanged volume —
    the dirty mask, not the mirror-list size, drives the traffic."""
    g = rmat_graph
    src = G.highest_out_degree_vertex(g)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, "oec")
    _, _, _, stats = gluon.sssp_distributed(
        sg, mesh, src, CFG, collect_stats=True, sync="mirror", meta=meta)
    per_round = _total_bytes_per_round(stats)
    # padded mirror capacity is static; the *dirty* payload is not
    static_cap = 2 * meta.total_mirrors * 4
    assert min(per_round) < static_cap
    assert per_round[-1] <= min(per_round[:3])


# ---------------- single-device subprocess fallback (slow) -----------------

PARITY_SCRIPT = r"""
import numpy as np, jax
from repro.core import graph as G
from repro.core.partition import partition
from repro.core import gluon
from repro.core.balancer import BalancerConfig

assert len(jax.devices()) == 4, jax.devices()
cfg = BalancerConfig(strategy="alb", threshold=64)
g = G.rmat(9, 8, seed=5)
src = G.highest_out_degree_vertex(g)
mesh = gluon.device_mesh(4)
baseline = g.num_vertices * 4 * 4
for policy in ["oec", "cvc"]:
    sg, meta = partition(g, 4, policy)
    ref, _, _ = gluon.sssp_distributed(sg, mesh, src, cfg)
    labels, _, _, stats = gluon.sssp_distributed(
        sg, mesh, src, cfg, collect_stats=True, sync="mirror", meta=meta)
    assert np.array_equal(np.asarray(labels), np.asarray(ref)), policy
    per_round = [sum(st.bytes_synced for st in pr) for pr in stats]
    assert all(b < baseline for b in per_round), (policy, per_round)
print("MIRROR_OK")
"""


@pytest.mark.slow
def test_mirror_parity_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", PARITY_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MIRROR_OK" in out.stdout
