"""int8 compressed gradient all-reduce: distributed correctness."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.gluon import device_mesh
from repro.optim.grad_compress import compressed_psum

mesh = device_mesh(4)
rng = np.random.default_rng(0)
local = jnp.asarray(rng.normal(size=(4, 64, 32)).astype(np.float32))

def f(g):
    return compressed_psum({"w": g[0]}, "dev")["w"]

out = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("dev"),),
                        out_specs=P(), check_rep=False))(local)
want = np.asarray(local).sum(axis=0)
got = np.asarray(out)
# error bounded by #participants * quantum
err = np.abs(got - want)
scale = np.abs(np.asarray(local)).max() / 127.0
assert err.max() <= 4 * scale + 1e-5, (err.max(), scale)
print("COMPRESS_OK", err.max())
"""


@pytest.mark.slow
def test_compressed_psum_multi_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COMPRESS_OK" in out.stdout
