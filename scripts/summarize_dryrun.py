"""Regenerate the EXPERIMENTS.md dry-run table from artifacts/dryrun."""
import glob, json, sys

def main(art='artifacts/dryrun'):
    rows = []
    for f in sorted(glob.glob(f'{art}/*.json')):
        if f.endswith('__cost.json'):
            continue
        d = json.load(open(f))
        m = d['memory']
        rows.append((d['arch'], d['shape'], d['mesh'],
                     m['argument_size_in_bytes'] / 1e9,
                     m['temp_size_in_bytes'] / 1e9,
                     d['collectives']['total_bytes'] / 1e9,
                     d['collectives']['counts'], d['compile_s']))
    print('| arch | shape | mesh | args GB/dev | temp GB/dev | '
          'coll GB/dev | compile s |')
    print('|---|---|---|---|---|---|---|')
    for r in rows:
        print(f'| {r[0]} | {r[1]} | {r[2]} | {r[3]:.2f} | {r[4]:.2f} '
              f'| {r[5]:.2f} | {r[7]:.0f} |')
    print(f'\n{len(rows)} cells, all compiled OK.')

if __name__ == '__main__':
    main(*sys.argv[1:])
