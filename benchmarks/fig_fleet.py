"""Fleet routing quality: affinity, bounded load, hedging, replay
(DESIGN.md section 13).

A single continuous-batching service (fig_serve.py) wins by packing
slots; a fleet of N services wins or loses on ROUTING.  This harness
runs a seeded Zipf workload over a 3-replica fleet and measures the
structural quantities the router is supposed to control — none of the
gates is wall-clock:

* **Cache affinity**: fleet-level hit rate with rendezvous affinity on
  vs the pure-P2C ablation (affinity off).  Affinity concentrates
  repeats of a key onto its owner replica, so the same per-replica LRU
  capacity answers more of the traffic.
* **Bounded load**: the trace-derived ceiling audit — no executed
  assignment may exceed ``ceil(c * (total + 1) / n)`` — plus the
  spread of per-replica served counts.
* **Hedging under stragglers**: throttled replicas force SLO-late
  queries; hedges must launch, losers must cancel, every fleet query
  must publish exactly once, and every published result must be
  bitwise equal to the standalone app run.
* **Replay**: the full routing trace re-derived offline must match the
  live decisions exactly — zero divergences.

Rows: ``fleet_route_{affinity|p2c}`` (derived: fleet hit rate, device
computations), ``fleet_balance`` (derived: per-replica served,
ceiling violations), ``fleet_hedge`` (derived: hedges
launched/cancelled, publish count, parity), ``fleet_replay``
(derived: trace rows, divergences).

Run directly (also the ``fleet`` selector of benchmarks.run):

    PYTHONPATH=src python -m benchmarks.fig_fleet          # full
    PYTHONPATH=src python -m benchmarks.fig_fleet --smoke  # CI gate

The gates are structural and run at every scale; ``--smoke`` only
shrinks the input.  ``run`` returns the number of gate failures and
the process exits non-zero unless (a) the affinity fleet's hit rate
>= the affinity-off pairing, (b) the trace audit finds zero
bounded-load ceiling violations and zero replay divergences in every
run, and (c) the straggler run publishes every query exactly once
with results bitwise equal to standalone runs — the acceptance gates
for the fleet layer.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import graph as G
from repro.core.apps import bfs
from repro.core.balancer import BalancerConfig
from repro.serve.fleet import (Fleet, RouterConfig, HedgePolicy,
                               replay, ceiling_violations)

from .common import emit, pick_sources
from .fig_serve import _traffic


def _run_fleet(g, traffic, cfg, affinity=True, seed=11,
               throttles=None, hedge_after=12, cache_capacity=64):
    """Build a 3-replica fleet, push the whole workload, drain."""
    fleet = Fleet(num_replicas=3, num_slots=4, cfg=cfg,
                  cache_capacity=cache_capacity,
                  router=RouterConfig(affinity=affinity,
                                      hedge_after=hedge_after),
                  hedge=HedgePolicy(max_hedges=1), seed=seed)
    fleet.register_graph("g", g)
    if throttles:
        for rid, t in throttles.items():
            fleet.replicas[rid].throttle = t
    fqids = [fleet.submit("g", "bfs", s) for s in traffic]
    fleet.run()
    return fleet, fqids


def _audit(fleet) -> tuple:
    """(replay divergences, ceiling violations) of a drained fleet."""
    return (replay(fleet.trace.rows),
            ceiling_violations(fleet.trace.rows))


def run(smoke: bool = False) -> int:
    scale = 9 if smoke else 12
    n_distinct = 12 if smoke else 32
    n_queries = 36 if smoke else 128
    g = G.rmat(scale, 8 if smoke else 16, seed=1)
    cfg = BalancerConfig(strategy="alb", threshold=64)
    traffic = _traffic(pick_sources(g, n_distinct), n_queries)
    failures = 0

    # ---- affinity vs pure P2C: same traffic, same caches -------------
    audits, hit_rate = [], {}
    for name, affinity in (("affinity", True), ("p2c", False)):
        fleet, _ = _run_fleet(g, traffic, cfg, affinity=affinity)
        s = fleet.summary()
        audits.append(_audit(fleet))
        hit_rate[name] = s["fleet_hit_rate"]
        emit(f"fleet_route_{name}", 0.0,
             f"hit_rate={s['fleet_hit_rate']:.3f};"
             f"computations={s['device_computations']};"
             f"steps={s['steps']}")
        if name == "affinity":
            served = s["per_replica_served"]
            emit("fleet_balance", 0.0,
                 f"served={'/'.join(str(v) for v in served)};"
                 f"ceiling_violations={len(audits[0][1])}")
    if hit_rate["affinity"] < hit_rate["p2c"]:
        print(f"FAIL: affinity routing hit rate "
              f"{hit_rate['affinity']:.3f} below the pure-P2C "
              f"ablation's {hit_rate['p2c']:.3f} (rendezvous affinity "
              f"should concentrate repeats)", file=sys.stderr)
        failures += 1

    # ---- straggler run: throttled replicas force hedges --------------
    fleet, fqids = _run_fleet(
        g, traffic[:n_queries // 2], cfg, seed=13,
        throttles={0: 5, 1: 5, 2: 5}, hedge_after=3,
        cache_capacity=0)
    audits.append(_audit(fleet))
    s = fleet.summary()
    recs = [fleet.poll(q) for q in fqids]
    published_once = (s["queries_served"] == len(fqids)
                      and all(r.result is not None for r in recs))
    parity = all(
        np.array_equal(np.asarray(r.result),
                       np.asarray(bfs(g, r.source, cfg).labels))
        for r in recs)
    emit("fleet_hedge", 0.0,
         f"launched={s['hedges_launched']};"
         f"cancelled={s['hedges_cancelled']};"
         f"published={s['queries_served']}/{len(fqids)};"
         f"parity={int(parity)}")
    if not published_once:
        print("FAIL: straggler run did not publish every query "
              "exactly once", file=sys.stderr)
        failures += 1
    if not parity:
        print("FAIL: hedged fleet results diverge from standalone "
              "runs (determinism broken)", file=sys.stderr)
        failures += 1

    # ---- trace audit across every run --------------------------------
    divergences = sum(len(a[0]) for a in audits)
    violations = sum(len(a[1]) for a in audits)
    emit("fleet_replay", 0.0,
         f"rows={len(fleet.trace)};divergences={divergences};"
         f"violations={violations}")
    if divergences:
        print(f"FAIL: {divergences} routing decisions did not replay "
              f"bitwise from their recorded inputs", file=sys.stderr)
        failures += 1
    if violations:
        print(f"FAIL: {violations} assignments exceeded the "
              f"bounded-load ceiling", file=sys.stderr)
        failures += 1
    if not failures:
        print(f"# fleet gates OK: affinity hit rate "
              f"{hit_rate['affinity']:.3f} >= p2c "
              f"{hit_rate['p2c']:.3f}; 0 divergences; 0 ceiling "
              f"violations; {s['hedges_launched']} hedges raced "
              f"cleanly", file=sys.stderr)
    return failures


def main() -> int:
    smoke = "--smoke" in sys.argv[1:]
    if run(smoke=smoke):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
