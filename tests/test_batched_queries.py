"""Batched multi-source query engine acceptance (DESIGN.md section 7).

The contract: ``bfs_batch`` / ``sssp_batch`` over B sources return
labels **bitwise equal** to B sequential single-source runs with the
same configuration — for every load-balancing strategy, both round
modes (host-driven and fully-jit SPMD), both executor backends (xla
and pallas), and B in {1, 3, 8}.  The batched round plans bins, the
huge-bin inspector, and the LB prefix-sum deal once over the union
frontier, so equality here proves per-query activity masking is exact
(an inactive (vertex, query) pair must contribute the combiner's
identity, nothing else).

The distributed runtime is covered too: replicated all-reduce and the
master/mirror boundary exchange both accept the batch axis; the
4-device cases run natively in the CI multidev job
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``) and skip
under the plain tier-1 run.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import graph as G
from repro.core.balancer import BalancerConfig, RoundStats, relax, relax_spmd
from repro.core.frontier import single_source, single_sources, union_frontier
from repro.core import operators as ops
from repro.core import gluon
from repro.core import wire
from repro.core.partition import partition
from repro.core.apps import bfs, sssp, bfs_batch, sssp_batch

STRATS = ["vertex", "twc", "edge_lb", "alb"]
BATCHES = [1, 3, 8]
NDEV = 4
multidevice = pytest.mark.skipif(
    len(jax.devices()) < NDEV,
    reason=f"needs {NDEV} devices (CI sets "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={NDEV})")


@pytest.fixture(scope="module")
def graph():
    return G.rmat(8, 8, seed=7)        # power-law: the inspector fires


@pytest.fixture(scope="module")
def sources(graph):
    """8 distinct sources: the top-degree hub plus spread-out picks, so
    per-query frontiers overlap only partially (the interesting case
    for union-frontier masking)."""
    deg = np.asarray(graph.out_degrees())
    picks, seen = [], set()
    for v in np.argsort(-deg):
        if deg[v] > 0 and int(v) not in seen:
            picks.append(int(v))
            seen.add(int(v))
        if len(picks) == 8:
            break
    return picks


def _cfg(strategy, use_pallas=False):
    return BalancerConfig(strategy=strategy, threshold=64,
                          use_pallas=use_pallas)


@pytest.fixture(scope="module")
def seq_cache(graph, sources):
    """Sequential single-source references, computed once per
    (app, strategy, backend, mode) and shared across the B sweep."""
    cache = {}

    def get(app, strategy, use_pallas, mode):
        key = (app.__name__, strategy, use_pallas, mode)
        if key not in cache:
            cfg = _cfg(strategy, use_pallas)
            cache[key] = np.stack([
                np.asarray(app(graph, s, cfg, mode=mode).labels)
                for s in sources])
        return cache[key]

    return get


# ---------------- the acceptance sweep ------------------------------------

@pytest.mark.parametrize("b", BATCHES)
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("mode", ["host", "spmd"])
@pytest.mark.parametrize("strategy", STRATS)
def test_sssp_batch_bitwise_parity(graph, sources, seq_cache, strategy,
                                   mode, use_pallas, b):
    cfg = _cfg(strategy, use_pallas)
    out = sssp_batch(graph, sources[:b], cfg, mode=mode)
    ref = seq_cache(sssp, strategy, use_pallas, mode)[:b]
    assert out.labels.shape == (b, graph.num_vertices)
    np.testing.assert_array_equal(np.asarray(out.labels), ref)


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("mode", ["host", "spmd"])
@pytest.mark.parametrize("strategy", STRATS)
def test_bfs_batch_bitwise_parity_b8(graph, sources, seq_cache, strategy,
                                     mode, use_pallas):
    cfg = _cfg(strategy, use_pallas)
    out = bfs_batch(graph, sources, cfg, mode=mode)
    ref = seq_cache(bfs, strategy, use_pallas, mode)
    np.testing.assert_array_equal(np.asarray(out.labels), ref)


# ---------------- round-level invariants ----------------------------------

def test_single_round_union_inspector(graph, sources):
    """One batched round == B independent rounds, and the batched stats
    report the union frontier + per-query sizes."""
    v = graph.num_vertices
    cfg = _cfg("alb")
    b = 3
    dist = jnp.full((b, v), G.INF, jnp.int32) \
        .at[jnp.arange(b), jnp.asarray(sources[:b])].set(0)
    fr = single_sources(v, sources[:b])
    batched, st = relax(graph, dist, dist, fr, cfg, ops.SSSP_RELAX,
                        collect_stats=True)
    for q in range(b):
        one, _ = relax(graph, dist[q], dist[q],
                       single_source(v, sources[q]), cfg, ops.SSSP_RELAX)
        np.testing.assert_array_equal(np.asarray(batched[q]),
                                      np.asarray(one))
    union = np.asarray(union_frontier(fr))
    assert st.frontier_size == union.sum()
    np.testing.assert_array_equal(st.frontier_per_query,
                                  np.asarray(fr).sum(axis=1))


def test_spmd_batched_stats_match_host(graph, sources):
    v = graph.num_vertices
    cfg = _cfg("alb")
    b = 3
    dist = jnp.full((b, v), G.INF, jnp.int32) \
        .at[jnp.arange(b), jnp.asarray(sources[:b])].set(0)
    fr = single_sources(v, sources[:b])
    _, hst = relax(graph, dist, dist, fr, cfg, ops.SSSP_RELAX,
                   collect_stats=True)
    _, dst = relax_spmd(graph, dist, dist, fr, cfg, ops.SSSP_RELAX,
                        collect_stats=True)
    sst = RoundStats.from_device(dst)
    assert sst.frontier_size == hst.frontier_size
    assert sst.edges_twc == hst.edges_twc
    assert sst.edges_lb == hst.edges_lb
    np.testing.assert_array_equal(sst.frontier_per_query,
                                  hst.frontier_per_query)


def test_retired_queries_stop_contributing(graph, sources):
    """A query whose frontier has emptied must not affect the rest of
    the batch: batching a converged query with a live one equals the
    live one's own run."""
    cfg = _cfg("alb")
    # near, quickly-converging query: the hub; far query: a low-degree pick
    out = sssp_batch(graph, sources[:2], cfg)
    solo0 = sssp(graph, sources[0], cfg)
    solo1 = sssp(graph, sources[1], cfg)
    np.testing.assert_array_equal(np.asarray(out.labels[0]),
                                  np.asarray(solo0.labels))
    np.testing.assert_array_equal(np.asarray(out.labels[1]),
                                  np.asarray(solo1.labels))
    assert out.rounds == max(solo0.rounds, solo1.rounds)


def test_batch_of_identical_sources(graph, sources):
    """Degenerate batch: B copies of one source — the union equals each
    query's frontier every round, all rows must match the solo run."""
    cfg = _cfg("alb")
    out = bfs_batch(graph, [sources[0]] * 4, cfg)
    ref = np.asarray(bfs(graph, sources[0], cfg).labels)
    for q in range(4):
        np.testing.assert_array_equal(np.asarray(out.labels[q]), ref)


# ---------------- distributed runtime (4 devices, CI multidev job) --------

@multidevice
@pytest.mark.parametrize("use_pallas", [False, True])
def test_batched_replicated_sync_4dev(use_pallas):
    g = G.rmat(9, 8, seed=5)
    deg = np.asarray(g.out_degrees())
    srcs = [int(x) for x in np.argsort(-deg)[:4]]
    cfg = _cfg("alb", use_pallas)
    mesh = gluon.device_mesh(NDEV)
    sg, _ = partition(g, NDEV, "oec")
    ref = np.stack([np.asarray(sssp(g, s, _cfg("alb")).labels)
                    for s in srcs])
    labels, _, _ = gluon.sssp_batch_distributed(sg, mesh, srcs, cfg)
    np.testing.assert_array_equal(np.asarray(labels), ref)


@multidevice
@pytest.mark.parametrize("policy", ["oec", "cvc"])
def test_batched_mirror_sync_4dev(policy):
    """The ISSUE's 4-host-device mirror-sync case: B queries share the
    dirty-tracked boundary exchange — one [B] vector per dirty vertex —
    and still land bitwise on the sequential references."""
    g = G.rmat(9, 8, seed=5)
    deg = np.asarray(g.out_degrees())
    srcs = [int(x) for x in np.argsort(-deg)[:4]]
    b = len(srcs)
    cfg = _cfg("alb")
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, policy)
    ref = np.stack([np.asarray(sssp(g, s, cfg).labels) for s in srcs])
    labels, rounds, _, stats = gluon.sssp_batch_distributed(
        sg, mesh, srcs, cfg, sync="mirror", meta=meta,
        collect_stats=True)
    np.testing.assert_array_equal(np.asarray(labels), ref)
    # payload accounting: every exchanged vertex ships its int32 index
    # word plus its [B] payload (the logical-bytes definition of
    # tests/test_mirror_sync.py's accounting regression), and the
    # boundary exchange still undercuts the replicated all-reduce's
    # B * V * itemsize * D baseline
    for per_round in stats:
        for st in per_round:
            assert st.bytes_synced == st.mirrors_synced * (
                wire.INDEX_BYTES + b * 4)
    baseline = b * g.num_vertices * 4 * NDEV
    per_round_bytes = [sum(st.bytes_synced for st in pr) for pr in stats]
    assert len(per_round_bytes) == rounds
    assert all(x < baseline for x in per_round_bytes)


@multidevice
def test_batched_bfs_distributed_4dev():
    g = G.rmat(9, 8, seed=5)
    deg = np.asarray(g.out_degrees())
    srcs = [int(x) for x in np.argsort(-deg)[:3]]
    cfg = _cfg("alb")
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, "oec")
    ref = np.stack([np.asarray(bfs(g, s, cfg).labels) for s in srcs])
    for sync in ["replicated", "mirror"]:
        labels = gluon.bfs_batch_distributed(
            sg, mesh, srcs, cfg, sync=sync, meta=meta)[0]
        np.testing.assert_array_equal(np.asarray(labels), ref, err_msg=sync)
