"""Fig 8 analogue: cyclic vs blocked edge distribution inside the LB
executor (paper: cyclic up to 4x faster; here the structural effect is
contiguous vs strided gathers in the mapping kernel)."""
from __future__ import annotations

from repro.core.balancer import BalancerConfig
from repro.core import graph as G
from repro.core.apps import sssp, bfs

from .common import bench_graphs, timed, emit


def run(scale: int = 13):
    g = bench_graphs(scale)["rmat"]
    src = G.highest_out_degree_vertex(g)
    out = {}
    for dist in ["cyclic", "blocked"]:
        for use_pallas in [False, True]:
            cfg = BalancerConfig(strategy="alb", threshold=1024,
                                 distribution=dist,
                                 use_pallas=use_pallas)
            tag = f"fig8/{dist}/{'pallas' if use_pallas else 'xla'}"
            secs = timed(lambda: sssp(g, src, cfg, max_rounds=200))
            out[(dist, use_pallas)] = secs
            emit(tag, secs)
    for up in [False, True]:
        c, b = out[("cyclic", up)], out[("blocked", up)]
        emit(f"fig8/summary/{'pallas' if up else 'xla'}", c,
             f"cyclic_speedup={b / c:.2f}x")
    locality_metric()
    return out


if __name__ == "__main__":
    run()


def locality_metric(scale: int = 13, lanes: int = 128):
    """Fig 4's actual claim, measured structurally: for each 128-lane
    group of edge ids, how many distinct prefix-array entries (source
    slots) do the lanes' binary searches land on?  Cyclic keeps a
    lane-group inside ~1 source run (coalesced col_idx loads, uniform
    search path); blocked strides lanes by w so every lane diverges.
    """
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels import edge_lb

    g = bench_graphs(scale)["rmat"]
    deg = np.asarray(g.out_degrees())
    huge = np.argsort(deg)[-64:]                  # the huge bin
    hdeg = jnp.asarray(deg[huge].astype(np.int32))
    start_e = jnp.cumsum(hdeg) - hdeg
    row = jnp.asarray(np.asarray(g.row_ptr)[huge].astype(np.int32))
    val = jnp.zeros_like(row)
    total = jnp.sum(hdeg)

    out = {}
    for dist in ["cyclic", "blocked"]:
        ge, j, v, m = edge_lb.edge_lb_map(start_e, row, val, total,
                                          int(total), distribution=dist)
        j = np.asarray(j)[np.asarray(m)]
        n = (len(j) // lanes) * lanes
        groups = j[:n].reshape(-1, lanes)
        spans = groups.max(axis=1) - groups.min(axis=1) + 1
        out[dist] = float(spans.mean())
        emit(f"fig4/locality/{dist}", 0.0,
             f"mean_distinct_src_per_lane_group={spans.mean():.2f}")
    emit("fig4/locality/summary", 0.0,
         f"blocked/cyclic_divergence_ratio="
         f"{out['blocked'] / out['cyclic']:.1f}x")
    return out
