"""Table 2 analogue: execution time per (input x app x strategy).

The paper's headline: ALB ~matches TWC on flat inputs (road, orkut)
and beats it up to 4x on power-law inputs (rmat*).

Besides the four strategies in the host-driven round, an ``alb_spmd``
row times the fully-jit static-capacity round (the one the distributed
runtime executes inside ``shard_map``) on one device, quantifying the
cost of static capacities + ``lax.cond`` vs per-round host dispatch.
"""
from __future__ import annotations

from repro.core.balancer import BalancerConfig
from repro.core import graph as G
from repro.core.apps import bfs, sssp, cc, kcore, pagerank

from .common import bench_graphs, symmetrized, timed, emit

STRATEGIES = ["vertex", "twc", "edge_lb", "alb"]
THRESHOLD = 1024


def run(scale: int = 13):
    graphs = bench_graphs(scale)
    rows = {}
    for gname, g in graphs.items():
        src = (G.highest_out_degree_vertex(g) if gname != "road" else 0)
        sym = symmetrized(g)
        for strat in STRATEGIES:
            cfg = BalancerConfig(strategy=strat, threshold=THRESHOLD)
            apps = {
                "bfs": lambda: bfs(g, src, cfg, max_rounds=200),
                "sssp": lambda: sssp(g, src, cfg, max_rounds=200),
                "cc": lambda: cc(sym, cfg, max_rounds=200),
                "kcore": lambda: kcore(sym, 10, cfg, max_rounds=200),
                "pr": lambda: pagerank(g, cfg=cfg, max_rounds=20,
                                       tol=0.0),
            }
            for aname, fn in apps.items():
                secs = timed(fn, repeats=3)
                rows[(gname, aname, strat)] = secs
                emit(f"table2/{gname}/{aname}/{strat}", secs)
        # the distributed runtime's fully-jit round, on one device
        spmd_cfg = BalancerConfig(strategy="alb", threshold=THRESHOLD)
        spmd_apps = {
            "bfs": lambda: bfs(g, src, spmd_cfg, max_rounds=200,
                               mode="spmd"),
            "sssp": lambda: sssp(g, src, spmd_cfg, max_rounds=200,
                                 mode="spmd"),
            "cc": lambda: cc(sym, spmd_cfg, max_rounds=200, mode="spmd"),
            "kcore": lambda: kcore(sym, 10, spmd_cfg, max_rounds=200,
                                   mode="spmd"),
            "pr": lambda: pagerank(g, cfg=spmd_cfg, max_rounds=20,
                                   tol=0.0, mode="spmd"),
        }
        for aname, fn in spmd_apps.items():
            secs = timed(fn, repeats=3)
            rows[(gname, aname, "alb_spmd")] = secs
            emit(f"table2/{gname}/{aname}/alb_spmd", secs)
    # derived: ALB speedup vs TWC per cell (the paper's metric)
    for (gname, aname), _ in {(k[0], k[1]): None for k in rows}.items():
        twc = rows[(gname, aname, "twc")]
        alb = rows[(gname, aname, "alb")]
        emit(f"table2/{gname}/{aname}/alb_speedup_vs_twc", alb,
             f"speedup={twc / alb:.2f}x")
    return rows


if __name__ == "__main__":
    run()
