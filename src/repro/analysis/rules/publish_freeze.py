"""``publish-freeze``: arrays become shared state only via freeze().

The serve layer publishes ndarrays into aliased, long-lived
structures: ``ResultCache`` entries (shared by every cache hit and
coalesced follower), ``q.result`` (returned verbatim from
``poll()``), and ``ServiceStats`` fields.  A writable array published
there lets one caller corrupt every other caller's answer — a bug
class this repo has already shipped and re-fixed once.  Every value
stored into those sinks must flow through
:func:`repro.serve.publish.freeze` (which calls
``setflags(write=False)``) first: either the stored expression is a
``freeze(...)`` call, or it is a name that was frozen earlier in the
same function (``x = freeze(x)`` / ``x.setflags(write=False)``).
"""
from __future__ import annotations

import ast
from typing import List, Set

from .. import astutil
from ..findings import Finding
from ..registry import Rule, register_rule

RULE_ID = "publish-freeze"

_FREEZE_FNS = {"freeze"}
_ARRAYISH = {"np.asarray", "numpy.asarray", "np.array", "numpy.array",
             "np.copy", "numpy.copy"}


def _is_freeze_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and (astutil.dotted(node.func) or "").split(".")[-1]
            in _FREEZE_FNS)


def _frozen_names(fn: ast.AST) -> Set[str]:
    """Names frozen somewhere in ``fn``: ``x = freeze(...)``,
    ``freeze(x)``, or ``x.setflags(write=False)``."""
    frozen: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _is_freeze_call(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    frozen.add(t.id)
        if isinstance(node, ast.Call):
            if _is_freeze_call(node):
                for a in node.args:
                    if isinstance(a, ast.Name):
                        frozen.add(a.id)
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "setflags"
                    and isinstance(node.func.value, ast.Name)):
                frozen.add(node.func.value.id)
    return frozen


def _value_ok(value: ast.AST, frozen: Set[str]) -> bool:
    """Whether a published value is provably frozen (or array-free)."""
    if _is_freeze_call(value):
        return True
    if isinstance(value, ast.Name):
        return value.id in frozen
    if isinstance(value, ast.Constant):
        return True  # None / scalars
    if isinstance(value, (ast.Tuple, ast.List)):
        return all(_value_ok(el, frozen) for el in value.elts)
    if isinstance(value, ast.IfExp):
        return (_value_ok(value.body, frozen)
                and _value_ok(value.orelse, frozen))
    return False


def _is_cache_sink(target: ast.AST) -> bool:
    # self._entries[...] = ...  (ResultCache storage dict)
    if isinstance(target, ast.Subscript):
        return (astutil.dotted(target.value) or "").endswith(
            "._entries")
    return False


def _is_result_sink(target: ast.AST) -> bool:
    # q.result = ... (what poll() hands back)
    return isinstance(target, ast.Attribute) \
        and target.attr == "result"


def _is_stats_sink(target: ast.AST, value: ast.AST) -> bool:
    # an ndarray-producing expression stored on a *stats attribute
    if not isinstance(target, ast.Attribute):
        return False
    d = astutil.dotted(target) or ""
    if ".stats." not in "." + d + ".":
        owner = astutil.dotted(target.value) or ""
        if not owner.endswith("stats"):
            return False
    for node in ast.walk(value):
        if isinstance(node, ast.Call):
            fd = astutil.dotted(node.func) or ""
            if fd in _ARRAYISH or fd.endswith(".copy"):
                return True
    return False


def check(ctx) -> List[Finding]:
    """Run the publish-freeze pass over one file (serve/ only)."""
    if not ctx.in_dir("repro", "serve"):
        return []
    out: List[Finding] = []
    fns = [n for n in ast.walk(ctx.tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in fns:
        frozen = _frozen_names(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            for target in node.targets:
                sink = None
                if _is_cache_sink(target):
                    sink = "ResultCache entry"
                elif _is_result_sink(target):
                    sink = "poll() result"
                elif _is_stats_sink(target, node.value):
                    sink = "ServiceStats field"
                if sink is None:
                    continue
                if not _value_ok(node.value, frozen):
                    out.append(ctx.finding(
                        node, RULE_ID,
                        f"{sink} published without freeze(): shared "
                        f"ndarrays must pass through "
                        f"repro.serve.publish.freeze "
                        f"(setflags(write=False)) first"))
    return out


register_rule(Rule(
    id=RULE_ID,
    description="ndarrays stored into ResultCache / poll() results / "
                "ServiceStats must flow through the freeze() helper",
    check=check,
))
