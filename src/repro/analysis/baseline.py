"""Committed baseline of grandfathered findings.

The baseline file (``analysis-baseline.txt`` at the repo root) lists
pre-existing findings that are tolerated until someone fixes them.
Entries are tab-separated ``path<TAB>rule<TAB>message`` — no line
numbers, so unrelated edits that shift code do not churn the file.
Duplicate lines grandfather that many occurrences.

Two hygiene properties are enforced at load/apply time:

* ``src/repro/core`` and ``src/repro/serve`` may never be baselined —
  the engine and the serving layer carry the invariants this linter
  exists to protect, so violations there are fixed or pragma'd with a
  justification, never grandfathered.
* Stale entries (no longer matching any finding) are reported so the
  baseline only ever shrinks; refresh with ``--write-baseline``.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterable, List, Tuple

from .findings import Finding

# "src/repro/core" subsumes every file under core/ (wire.py included);
# "src/repro/serve" likewise covers serve/fleet.
PROTECTED_PREFIXES = ("src/repro/core", "src/repro/serve")


def load_baseline(path) -> Counter:
    """Parse a baseline file into a ``Counter`` of baseline keys.

    Missing file -> empty baseline.  Blank lines and ``#`` comments
    are skipped; anything else must be the three tab-separated
    fields.
    """
    counts: Counter = Counter()
    try:
        text = open(path, "r", encoding="utf-8").read()
    except FileNotFoundError:
        return counts
    for lineno, line in enumerate(text.splitlines(), start=1):
        line = line.rstrip("\n")
        if not line.strip() or line.lstrip().startswith("#"):
            continue
        parts = line.split("\t")
        if len(parts) != 3:
            raise ValueError(
                f"{path}:{lineno}: malformed baseline entry "
                f"(want path<TAB>rule<TAB>message): {line!r}")
        counts[tuple(parts)] += 1
    return counts


def protected_violations(baseline: Counter) -> List[str]:
    """Baseline entries that illegally grandfather protected paths."""
    bad = []
    for (path, rule, message), n in sorted(baseline.items()):
        norm = path.replace("\\", "/").lstrip("./")
        if any(norm.startswith(p) for p in PROTECTED_PREFIXES):
            bad.append(f"{path}\t{rule}\t{message}")
    return bad


def apply_baseline(
    findings: Iterable[Finding],
    baseline: Counter,
) -> Tuple[List[Finding], int, List[tuple]]:
    """Filter ``findings`` through the baseline.

    Returns ``(kept, matched, stale)``: findings not covered by the
    baseline, how many were grandfathered, and baseline keys that
    matched nothing (candidates for deletion).
    """
    remaining = Counter(baseline)
    kept: List[Finding] = []
    matched = 0
    for f in findings:
        if remaining.get(f.baseline_key, 0) > 0:
            remaining[f.baseline_key] -= 1
            matched += 1
        else:
            kept.append(f)
    stale = sorted(k for k, n in remaining.items() if n > 0)
    return kept, matched, stale


def render_baseline(findings: Iterable[Finding]) -> str:
    """Serialize ``findings`` as baseline file text."""
    lines = [
        "# repro.analysis baseline — grandfathered findings.",
        "# path<TAB>rule<TAB>message; regenerate with",
        "#   PYTHONPATH=src python -m repro.analysis "
        "--write-baseline <paths>",
        "# src/repro/core and src/repro/serve may not appear here.",
    ]
    for key in sorted(f.baseline_key for f in findings):
        lines.append("\t".join(key))
    return "\n".join(lines) + "\n"
