"""Adaptive serving fleet: replicated query engines behind a
tail-aware router (DESIGN.md section 13).

The fleet layer runs N :class:`~repro.serve.engine.QueryService`
replicas behind a router that composes cache-affinity rendezvous
hashing, bounded-load redirection, and power-of-two-choices admission
scored by a tail-risk estimate; stragglers are hedged conditionally
on the SLO and cancelled on first finish; a feedback controller
steers the scoring weights against a p95 rounds-in-system target; and
every executed routing decision lands in a replayable
:class:`RoutingTrace` — the fleet's determinism witness.

Entry points: build a :class:`Fleet`, :meth:`~Fleet.register_graph`,
:meth:`~Fleet.submit`, :meth:`~Fleet.run`, then audit
``replay(fleet.trace.rows)`` and ``ceiling_violations(...)``.
"""
from .router import (RouterConfig, DecisionInputs, decide,
                     rendezvous_order, load_ceiling,
                     FeedbackController)
from .trace import (TraceRow, Divergence, RoutingTrace, replay,
                    ceiling_violations)
from .replica import ReplicaHandle
from .hedge import HedgePolicy, hedgeable
from .fleet import Fleet, FleetQuery

__all__ = [
    "RouterConfig", "DecisionInputs", "decide", "rendezvous_order",
    "load_ceiling", "FeedbackController",
    "TraceRow", "Divergence", "RoutingTrace", "replay",
    "ceiling_violations",
    "ReplicaHandle", "HedgePolicy", "hedgeable",
    "Fleet", "FleetQuery",
]
