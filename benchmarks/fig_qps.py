"""Batched query throughput: queries/sec vs batch size (DESIGN.md
section 7).

The paper's ALB amortizes a load-balancing decision across one
frontier; the batched engine amortizes it across B *queries* — bins,
the huge-bin inspector, and the LB prefix-sum deal run once over the
union frontier.  This harness measures the payoff as queries/sec of
``bfs_batch`` / ``sssp_batch`` on the power-law (rmat) input.

The workload is FIXED — the same 8 sources every time — and the batch
size varies: batch size B serves it as 8/B batches (B=1 is exactly 8
sequential single-source runs, the pre-batching baseline).  Holding
the work constant makes the comparison honest and the win structural:
a bigger B shares more per-round fixed work (host sync, compaction,
kernel launches) across the same queries, so queries/sec rises with B
(per-query heterogeneity cannot penalize a batch size the way a
varying workload would — a batch's round count is the max over its
members either way).

Rows: ``qps_<app>_<mode>_b<B>,us_per_workload,qps=<queries/sec>``.

Run directly (also wired as the ``qps`` selector of benchmarks.run):

    PYTHONPATH=src python -m benchmarks.fig_qps            # host rounds
    PYTHONPATH=src python -m benchmarks.fig_qps --spmd     # + spmd rounds
    PYTHONPATH=src python -m benchmarks.fig_qps --smoke    # CI smoke

``--smoke`` shrinks the input, runs one app/mode, and exits non-zero
if batching fails to pay: qps at the largest batch must beat qps at
B=1 — the cheap always-true core of the monotonicity claim (full
monotonicity is reported but not asserted; CI boxes are noisy timers).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import graph as G
from repro.core.apps import bfs_batch, sssp_batch
from repro.core.balancer import BalancerConfig

from .common import timed, emit, pick_sources


def run(smoke: bool = False, spmd: bool = False) -> dict:
    scale = 10 if smoke else 12
    g = G.rmat(scale, 8 if smoke else 16, seed=1)
    cfg = BalancerConfig(strategy="alb", threshold=64)
    batch_sizes = [1, 2, 4, 8]
    apps = {"bfs": bfs_batch} if smoke else {"bfs": bfs_batch,
                                             "sssp": sssp_batch}
    # the fully-jit round is the distributed building block; on CPU CI
    # boxes it is slow enough that it is opt-in here
    modes = ["host"] + (["spmd"] if spmd and not smoke else [])
    n_queries = max(batch_sizes)
    sources = pick_sources(g, n_queries)
    results: dict = {}
    for app_name, driver in apps.items():
        for mode in modes:
            qps_curve = []
            for b in batch_sizes:
                chunks = [sources[i:i + b]
                          for i in range(0, n_queries, b)]

                def serve(_chunks=chunks):
                    for chunk in _chunks:
                        driver(g, chunk, cfg, mode=mode)

                secs = timed(serve, repeats=3)
                qps = n_queries / secs
                qps_curve.append(qps)
                emit(f"qps_{app_name}_{mode}_b{b}", secs,
                     f"qps={qps:.1f}")
            results[(app_name, mode)] = qps_curve
            mono = all(a <= b_ for a, b_ in zip(qps_curve, qps_curve[1:]))
            emit(f"qps_{app_name}_{mode}_monotone", 0.0,
                 f"monotone={mono}")
    return results


def main() -> int:
    smoke = "--smoke" in sys.argv[1:]
    results = run(smoke=smoke, spmd="--spmd" in sys.argv[1:])
    if smoke:
        for key, curve in results.items():
            if curve[-1] <= curve[0]:
                print(f"FAIL: {key}: qps at the largest batch "
                      f"({curve[-1]:.1f}) <= qps at B=1 ({curve[0]:.1f})",
                      file=sys.stderr)
                return 1
        print("smoke OK: batching increases queries/sec")
    return 0


if __name__ == "__main__":
    sys.exit(main())
