"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]  54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000 ssm_state=64."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4, chunk=256),
    attn_every=6,                    # 9 shared-block applications
    sub_quadratic=True,
)

SMOKE = CONFIG.scaled(num_layers=4, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=256,
                      attn_every=2,
                      ssm=SSMConfig(d_state=16, head_dim=8, expand=2,
                                    d_conv=4, chunk=32))
