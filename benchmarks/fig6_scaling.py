"""Fig 6/10 analogue: multi-device scaling of D-IrGL(TWC) vs
D-IrGL(ALB) — BSP rounds over partitioned graphs, 1..8 devices.

Re-execs itself with a forced host device count so the multi-device
run never contaminates the parent process's single-device state.
"""
from __future__ import annotations

import os
import subprocess
import sys

MAX_DEV = 8


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{MAX_DEV}").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run([sys.executable, "-m", "benchmarks.fig6_scaling",
                        "--inner"], env=env, cwd=root,
                       capture_output=True, text=True, timeout=3600)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr[-3000:])
        raise RuntimeError("fig6 inner run failed")


def inner():
    import time
    import jax
    import numpy as np
    from repro.core import graph as G
    from repro.core.partition import partition
    from repro.core import gluon
    from repro.core.balancer import BalancerConfig
    from .common import emit

    g = G.rmat(13, 16, seed=1)
    src = G.highest_out_degree_vertex(g)
    for ndev in [1, 2, 4, 8]:
        mesh = gluon.device_mesh(ndev)
        sg = partition(g, ndev, "oec")
        for strat in ["twc", "alb"]:
            cfg = BalancerConfig(strategy=strat, threshold=1024)
            # warmup (compile)
            gluon.sssp_distributed(sg, mesh, src, cfg, max_rounds=200)
            t0 = time.perf_counter()
            labels, rounds, _ = gluon.sssp_distributed(
                sg, mesh, src, cfg, max_rounds=200)
            secs = time.perf_counter() - t0
            emit(f"fig6/sssp/{strat}/gpus{ndev}", secs,
                 f"rounds={rounds}")


if __name__ == "__main__":
    if "--inner" in sys.argv:
        inner()
    else:
        run()
