"""Transformer building blocks: RMSNorm, RoPE, GQA & MLA attention,
SwiGLU/GELU MLP — pure-JAX, sharding-friendly, KV-cache-capable.

Conventions:
* params are plain nested dicts of jnp arrays (f32 master copies),
* compute runs in bf16 (mixed precision), reductions in f32,
* attention is *chunked* (online softmax over KV blocks) so prefill at
  32k lowers with O(seq) live memory; a Pallas flash kernel provides
  the TPU fast path (kernels/flash_attention.py).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale or 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale)


# ---------------------------------------------------------------------------
# normalization + rope
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]                 # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked causal attention (online softmax) — O(S) memory at any length
# ---------------------------------------------------------------------------

# 'chunked' (scan over KV blocks) is the production path; 'plain'
# (materialized scores, no scan) exists for the dry-run cost extraction:
# XLA's HloCostAnalysis counts a scan body ONCE regardless of trip
# count, so roofline FLOPs/bytes are extracted from scan-free lowerings
# (see launch/dryrun.py --cost-extract) and the scanned lowering is used
# for the memory/runnability proof.
_ATTN_IMPL = "chunked"


def set_attn_impl(impl: str):
    global _ATTN_IMPL
    assert impl in ("chunked", "plain")
    _ATTN_IMPL = impl


def plain_attention(q, k, v, *, causal: bool, q_offset=0,
                    kv_len: Optional[jax.Array] = None, chunk: int = 0):
    """Reference attention with materialized scores (no lax.scan)."""
    b, sq, h, hd = q.shape
    _, skv, hkv, _ = k.shape
    vd = v.shape[-1]
    g = h // hkv
    qh = (q.astype(jnp.float32) / math.sqrt(hd)).reshape(b, sq, hkv, g, hd)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qh, k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if kv_len is not None:
        mask = mask & (kpos[None, :] < kv_len)
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, vd).astype(q.dtype)


def attention(q, k, v, **kw):
    if _ATTN_IMPL == "plain":
        return plain_attention(q, k, v, **kw)
    return chunked_attention(q, k, v, **kw)


def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      kv_len: Optional[jax.Array] = None,
                      chunk: int = 1024):
    """q: [B, Sq, H, hd]; k/v: [B, Skv, Hkv, hd] (GQA: H % Hkv == 0).

    Scans KV in blocks with running (max, sum, acc) — the flash
    recurrence — so live memory is O(Sq * chunk) not O(Sq * Skv).
    q_offset: position of q[0] within the kv sequence (decode: Skv-1).
    kv_len: optional dynamic valid length of the kv cache.
    """
    b, sq, h, hd = q.shape
    _, skv, hkv, _ = k.shape
    vd = v.shape[-1]                 # MLA: v head dim may differ from qk
    g = h // hkv
    qh = (q.astype(jnp.float32) / math.sqrt(hd)).reshape(b, sq, hkv, g, hd)

    nchunk = -(-skv // chunk)
    pad = nchunk * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunk, chunk, hkv, hd)
    vc = v.reshape(b, nchunk, chunk, hkv, vd)

    qpos = q_offset + jnp.arange(sq)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, cidx = blk
        kpos = cidx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qh, kb.astype(jnp.float32))
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if kv_len is not None:
            mask = mask & (kpos[None, :] < kv_len)
        if pad:
            mask = mask & (kpos[None, :] < skv)
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        scale = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = (acc * scale[..., None]
                   + jnp.einsum("bqkgc,bckd->bqkgd", p,
                                vb.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, hkv, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, g, vd), jnp.float32)
    kc = jnp.moveaxis(kc, 1, 0)
    vc = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nchunk)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, vd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg):
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * hd)),
        "wk": _dense_init(ks[1], (d, hkv * hd)),
        "wv": _dense_init(ks[2], (d, hkv * hd)),
        "wo": _dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def gqa_apply(p, x, cfg, *, positions, cache=None, cache_index=None,
              attn_chunk=1024):
    """cache: optional dict {k: [B, Smax, Hkv, hd], v: ...}; when given
    with cache_index, performs a decode/prefill update and returns
    (out, new_cache)."""
    b, s, d = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    xc = x.astype(COMPUTE_DTYPE)
    q = xc @ p["wq"].astype(COMPUTE_DTYPE)
    k = xc @ p["wk"].astype(COMPUTE_DTYPE)
    v = xc @ p["wv"].astype(COMPUTE_DTYPE)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(COMPUTE_DTYPE)
        k = k + p["bk"].astype(COMPUTE_DTYPE)
        v = v + p["bv"].astype(COMPUTE_DTYPE)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        out = attention(q, k, v, causal=True, chunk=attn_chunk)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = {"k": ck, "v": cv}
        out = attention(q, ck, cv, causal=True,
                        q_offset=cache_index,
                        kv_len=cache_index + s, chunk=attn_chunk)
    out = out.reshape(b, s, h * hd) @ p["wo"].astype(COMPUTE_DTYPE)
    return out.astype(x.dtype), new_cache


def gqa_cache_shape(cfg, batch, max_len, dtype=COMPUTE_DTYPE):
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {"k": jax.ShapeDtypeStruct((batch, max_len, hkv, hd), dtype),
            "v": jax.ShapeDtypeStruct((batch, max_len, hkv, hd), dtype)}


# ---------------------------------------------------------------------------
# MLA attention (MiniCPM3 / DeepSeek-V2): low-rank compressed Q and KV;
# the decode cache stores only the compressed latent + rope key.
# ---------------------------------------------------------------------------

def mla_init(key, cfg):
    d, h = cfg.d_model, cfg.num_heads
    m = cfg.mla
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": _dense_init(ks[0], (d, m.q_lora_rank)),
        "wq_b": _dense_init(ks[1], (m.q_lora_rank, h * qk_dim)),
        "wkv_a": _dense_init(ks[2], (d, m.kv_lora_rank
                                     + m.qk_rope_head_dim)),
        "wkv_b": _dense_init(ks[3], (m.kv_lora_rank,
                                     h * (m.qk_nope_head_dim
                                          + m.v_head_dim))),
        "q_norm": jnp.zeros((m.q_lora_rank,), jnp.float32),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
        "wo": _dense_init(ks[4], (h * m.v_head_dim, d)),
    }


def mla_apply(p, x, cfg, *, positions, cache=None, cache_index=None,
              attn_chunk=1024):
    b, s, d = x.shape
    h = cfg.num_heads
    m = cfg.mla
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    xc = x.astype(COMPUTE_DTYPE)

    cq = rms_norm(xc @ p["wq_a"].astype(COMPUTE_DTYPE), p["q_norm"],
                  cfg.norm_eps)
    q = (cq @ p["wq_b"].astype(COMPUTE_DTYPE)).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = xc @ p["wkv_a"].astype(COMPUTE_DTYPE)
    ckv, k_rope = ckv_full[..., :m.kv_lora_rank], ckv_full[..., m.kv_lora_rank:]
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)                   # [B,S,1,rope_d]

    new_cache = None
    if cache is not None:
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype),
            (0, cache_index, 0))
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, cache_index, 0, 0))
        new_cache = {"ckv": ckv, "k_rope": k_rope}
        kv_len = cache_index + s
        q_offset = cache_index
    else:
        kv_len = None
        q_offset = 0

    # decompress k/v from the latent (the FLOPs-for-memory trade MLA makes)
    kv = (ckv @ p["wkv_b"].astype(COMPUTE_DTYPE)) \
        .reshape(b, ckv.shape[1], h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope,
                                  (*k_nope.shape[:-1], rope_d))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention(q_full, k, v, causal=True, q_offset=q_offset,
                    kv_len=kv_len, chunk=attn_chunk)
    out = out.reshape(b, s, h * vd) @ p["wo"].astype(COMPUTE_DTYPE)
    return out.astype(x.dtype), new_cache


def mla_cache_shape(cfg, batch, max_len, dtype=COMPUTE_DTYPE):
    m = cfg.mla
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, 1,
                                        m.qk_rope_head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str):
    ks = jax.random.split(key, 3)
    p = {"w_up": _dense_init(ks[0], (d_model, d_ff)),
         "w_down": _dense_init(ks[1], (d_ff, d_model))}
    if act == "silu":                      # swiglu needs the gate proj
        p["w_gate"] = _dense_init(ks[2], (d_model, d_ff))
    return p


def mlp_apply(p, x, act: str):
    xc = x.astype(COMPUTE_DTYPE)
    up = xc @ p["w_up"].astype(COMPUTE_DTYPE)
    if act == "silu":
        gate = jax.nn.silu(xc @ p["w_gate"].astype(COMPUTE_DTYPE))
        hidden = gate * up
    else:
        hidden = jax.nn.gelu(up)
    return (hidden @ p["w_down"].astype(COMPUTE_DTYPE)).astype(x.dtype)
