"""Fleet layer: rendezvous routing, bounded load, trace replay,
hedge races, and cancellation (DESIGN.md section 13).

The property-based half of this suite (hypothesis) is optional: when
hypothesis is not installed, the property tests are simply not
defined, while their deterministic fixed-input counterparts — which
cover the same invariants on pinned cases — always run.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import graph as G
from repro.core.apps import bfs
from repro.core.balancer import BalancerConfig
from repro.serve import QueryService, CANCELLED, DONE, RUNNING
from repro.serve.fleet import (Fleet, FleetQuery, RouterConfig,
                               DecisionInputs, decide,
                               rendezvous_order, load_ceiling,
                               FeedbackController, HedgePolicy,
                               hedgeable, TraceRow,
                               replay, ceiling_violations)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

CFG = BalancerConfig(strategy="alb", threshold=32)


@pytest.fixture(scope="module")
def rmat_g():
    return G.rmat(8, 8, seed=3)


def _sources(g, n, seed=0):
    deg = np.asarray(g.out_degrees())
    cand = np.flatnonzero(deg > 0)
    rng = np.random.default_rng(seed)
    picks = rng.choice(cand, size=n, replace=False)
    return [int(v) for v in picks]


def _zipf_traffic(sources, n, seed=7):
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(sources) + 1, dtype=np.float64)
    p = (1.0 / ranks) / np.sum(1.0 / ranks)
    return [sources[i] for i in rng.choice(len(sources), size=n, p=p)]


def _fleet(n=3, slots=4, cache=64, seed=1, **router_kw):
    return Fleet(num_replicas=n, cfg=CFG, num_slots=slots,
                 cache_capacity=cache, seed=seed,
                 router=RouterConfig(**router_kw))


# ---------------------------------------------------------------------------
# rendezvous hashing
# ---------------------------------------------------------------------------

def _keys(n):
    return [("g", "bfs", i) for i in range(n)]


def test_rendezvous_order_deterministic_permutation():
    for key in _keys(50):
        order = rendezvous_order(key, 5)
        assert sorted(order) == list(range(5))
        assert order == rendezvous_order(key, 5)


def test_rendezvous_removal_remaps_only_removed_keys():
    # dropping the last replica: keys whose affinity was NOT replica 4
    # keep their owner; keys it owned move somewhere else
    for key in _keys(200):
        before = rendezvous_order(key, 5)[0]
        after = rendezvous_order(key, 4)[0]
        if before != 4:
            assert after == before
        else:
            assert after != 4


def test_rendezvous_addition_steals_about_one_nth():
    # growing 4 -> 5 replicas: moved keys all move TO the new replica,
    # and the stolen fraction is ~1/5 of the keyspace
    keys = _keys(2000)
    moved = 0
    for key in keys:
        before = rendezvous_order(key, 4)[0]
        after = rendezvous_order(key, 5)[0]
        if after != before:
            assert after == 4
            moved += 1
    assert 0.1 < moved / len(keys) < 0.3


# ---------------------------------------------------------------------------
# decide(): affinity, spill, bounded load, P2C
# ---------------------------------------------------------------------------

def _inputs(loads, key=("g", "bfs", 0), kind="route", pair=None,
            scores=None, affinity=True, c=1.25, exclude=(),
            seq=0, fqid=0):
    n = len(loads)
    return DecisionInputs(
        seq=seq, fqid=fqid, kind=kind, key=key, loads=tuple(loads),
        scores=tuple(scores if scores is not None else loads),
        order=rendezvous_order(key, n),
        pair=tuple(pair if pair is not None else range(min(2, n))),
        capacity_factor=c, affinity=affinity, exclude=tuple(exclude))


def test_affinity_wins_under_ceiling():
    inp = _inputs([0, 0, 0])
    assert decide(inp) == (inp.order[0], "affinity")


def test_overloaded_affinity_spills():
    key = ("g", "bfs", 0)
    aff = rendezvous_order(key, 3)[0]
    loads = [0, 0, 0]
    loads[aff] = 10                  # ceiling = ceil(1.25*11/3) = 5
    others = [r for r in range(3) if r != aff]
    inp = _inputs(loads, key=key, pair=others)
    choice, reason = decide(inp)
    assert reason == "spill" and choice != aff


def test_p2c_picks_lower_scored_of_pair():
    inp = _inputs([1, 1, 1], affinity=False, pair=(0, 2),
                  scores=(9.0, 0.0, 3.0))
    assert decide(inp) == (2, "p2c")  # lower score of the PAIR, not
    #                                   the global minimum (replica 1)


def test_decision_never_exceeds_ceiling():
    # a pinned adversarial case: both P2C candidates over the ceiling
    # forces the least-loaded fallback, which is always under it
    inp = _inputs([9, 9, 0], affinity=False, pair=(0, 1),
                  scores=(1.0, 2.0, 50.0))
    choice, _ = decide(inp)
    ceil_ = load_ceiling(inp.loads, inp.capacity_factor)
    assert inp.loads[choice] + 1 <= ceil_
    assert choice == 2


def test_hedge_respects_exclusions():
    inp = _inputs([1, 1, 1], kind="hedge", pair=(0, 1), exclude=(0,))
    choice, reason = decide(inp)
    assert reason == "hedge" and choice != 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(st.integers(0, 10_000), st.integers(2, 8))
    def test_prop_rendezvous_remove_remaps_only_owned(src, n):
        key = ("g", "bfs", src)
        before = rendezvous_order(key, n + 1)[0]
        after = rendezvous_order(key, n)[0]
        if before != n:
            assert after == before
        else:
            assert after != n

    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.integers(0, 20), min_size=2, max_size=8),
           st.integers(0, 1000), st.booleans(),
           st.floats(1.0, 2.0), st.data())
    def test_prop_decide_bounded_load(loads, src, affinity, c, data):
        n = len(loads)
        pair = data.draw(
            st.lists(st.integers(0, n - 1), min_size=1, max_size=2,
                     unique=True))
        scores = data.draw(
            st.lists(st.floats(0, 100, allow_nan=False),
                     min_size=n, max_size=n))
        inp = _inputs(loads, key=("g", "bfs", src), pair=pair,
                      scores=scores, affinity=affinity, c=c)
        choice, _ = decide(inp)
        assert inp.loads[choice] + 1 <= load_ceiling(inp.loads, c)

    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.floats(0, 100, allow_nan=False),
                    min_size=3, max_size=8, unique=True),
           st.data())
    def test_prop_p2c_picks_lower_scored(scores, data):
        n = len(scores)
        pair = tuple(data.draw(
            st.lists(st.integers(0, n - 1), min_size=2, max_size=2,
                     unique=True)))
        inp = _inputs([0] * n, affinity=False, pair=pair,
                      scores=scores)
        choice, reason = decide(inp)
        assert reason == "p2c"
        assert choice == min(pair, key=lambda r: scores[r])


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------

def _drained_fleet(g, n_queries=30, seed=2, **kw):
    fleet = _fleet(seed=seed, **kw)
    fleet.register_graph("g", g)
    traffic = _zipf_traffic(_sources(g, 8), n_queries, seed=seed)
    fqids = [fleet.submit("g", "bfs", s) for s in traffic]
    fleet.run()
    return fleet, fqids, traffic


def test_trace_replays_exactly(rmat_g):
    fleet, _, _ = _drained_fleet(rmat_g)
    assert len(fleet.trace) >= 30
    assert replay(fleet.trace.rows) == []
    assert ceiling_violations(fleet.trace.rows) == []


def test_trace_deterministic_across_runs(rmat_g):
    a, _, _ = _drained_fleet(rmat_g, seed=5)
    b, _, _ = _drained_fleet(rmat_g, seed=5)
    assert a.trace.rows == b.trace.rows


def test_replay_reports_corruption(rmat_g):
    # regression: the replayer must DETECT divergence, not just pass
    # clean traces — flip one recorded choice and corrupt one row's
    # load vector, and both must be reported with their seq
    fleet, _, _ = _drained_fleet(rmat_g)
    rows = list(fleet.trace.rows)
    tampered = rows[3]
    wrong = (tampered.choice + 1) % len(tampered.inputs.loads)
    rows[3] = TraceRow(inputs=tampered.inputs, choice=wrong,
                       reason=tampered.reason)
    divs = replay(rows)
    assert [d.seq for d in divs] == [rows[3].inputs.seq]
    assert divs[0].recorded[0] == wrong
    assert divs[0].derived == (tampered.choice, tampered.reason)

    # a reason-only corruption is also a divergence
    rows[3] = TraceRow(inputs=tampered.inputs, choice=tampered.choice,
                       reason="spill" if tampered.reason != "spill"
                       else "p2c")
    assert [d.seq for d in replay(rows)] == [rows[3].inputs.seq]

    # and an over-ceiling load vector is caught by the ceiling audit
    heavy = dataclasses.replace(
        rows[5].inputs, loads=tuple(
            40 if r == rows[5].choice else 0
            for r in range(len(rows[5].inputs.loads))))
    assert ceiling_violations(
        [TraceRow(inputs=heavy, choice=rows[5].choice,
                  reason=rows[5].reason)]) == [heavy.seq]


def test_hedge_decisions_are_traced(rmat_g):
    fleet, _, _ = _drained_fleet(rmat_g, n_queries=16, seed=3,
                                 cache=0, hedge_after=2)
    for rep in fleet.replicas:
        rep.throttle = 1
    kinds = {row.inputs.kind for row in fleet.trace.rows}
    assert "route" in kinds
    hedge_rows = [r for r in fleet.trace.rows
                  if r.inputs.kind == "hedge"]
    for row in hedge_rows:
        assert row.choice not in row.inputs.exclude
        assert row.reason == "hedge"


# ---------------------------------------------------------------------------
# hedge race: parity, single publication, cancellation
# ---------------------------------------------------------------------------

def test_hedge_race_parity_and_single_freeze(rmat_g, monkeypatch):
    g = rmat_g
    import repro.serve.fleet.fleet as fleet_mod
    calls = []
    real_freeze = fleet_mod.freeze

    def spy(arr):
        calls.append(id(arr))
        return real_freeze(arr)

    monkeypatch.setattr(fleet_mod, "freeze", spy)

    fleet = _fleet(cache=0, seed=4, hedge_after=2)
    fleet.register_graph("g", g)
    for rep in fleet.replicas:       # uniform throttle: every query
        rep.throttle = 4             # goes SLO-late, every hedge races
    srcs = _sources(g, 10, seed=1)
    fqids = [fleet.submit("g", "bfs", s) for s in srcs]
    summary = fleet.run()

    assert summary["hedges_launched"] > 0
    # exactly one freeze() per fleet query — the loser of each race
    # never reaches the publication choke point
    assert len(calls) == len(fqids)
    assert summary["queries_served"] == len(fqids)
    for fqid, s in zip(fqids, srcs):
        rec = fleet.poll(fqid)
        assert rec.status == DONE and rec.winner is not None
        ref = np.asarray(bfs(g, s, CFG).labels)
        assert np.array_equal(np.asarray(rec.result), ref)
        assert not rec.result.flags.writeable
        # every losing submission was cancelled (or finished and was
        # dropped) — none is still running
        for rid, rqid in rec.submissions:
            q = fleet.replicas[rid].svc.poll(rqid)
            assert q.status in (DONE, CANCELLED)
            if rid != rec.winner:
                assert q.status == CANCELLED
    # fleet accounting counts each query once despite the duplicates
    assert summary["queries_served"] == len(fqids)
    assert summary["device_computations"] >= len(fqids)


def test_hedges_skipped_when_fleet_saturated(rmat_g):
    # capacity-conditional hedging: with the whole fleet near the
    # ceiling, hedge launches must not push any replica over it
    fleet, _, _ = _drained_fleet(rmat_g, n_queries=40, seed=6,
                                 cache=0, hedge_after=1)
    assert ceiling_violations(fleet.trace.rows) == []


# ---------------------------------------------------------------------------
# engine cancellation (the serve-layer hook hedging relies on)
# ---------------------------------------------------------------------------

def _svc(g, slots=2, cache=0):
    svc = QueryService(num_slots=slots, cfg=CFG,
                       cache_capacity=cache)
    svc.register_graph("g", g)
    return svc


def test_cancel_queued_query(rmat_g):
    svc = _svc(rmat_g, slots=1)
    a = svc.submit("g", "bfs", _sources(rmat_g, 2)[0])
    b = svc.submit("g", "bfs", _sources(rmat_g, 2)[1])
    svc.step()                       # a runs, b still queued
    assert svc.cancel(b)
    assert svc.poll(b).status == CANCELLED
    svc.run()
    assert svc.poll(a).status == DONE
    assert not svc.cancel(a)         # DONE is not cancellable
    assert not svc.cancel(b)         # cancel is idempotent-false


def test_cancel_running_query_frees_slot(rmat_g):
    g = rmat_g
    svc = _svc(g, slots=1)
    srcs = _sources(g, 2)
    a = svc.submit("g", "bfs", srcs[0])
    b = svc.submit("g", "bfs", srcs[1])
    svc.step()
    assert svc.poll(a).status == RUNNING
    assert svc.cancel(a)
    assert svc.poll(a).status == CANCELLED
    svc.run()                        # b must still complete, in the
    qb = svc.poll(b)                 # slot the cancel released
    assert qb.status == DONE
    assert np.array_equal(np.asarray(qb.result),
                          np.asarray(bfs(g, srcs[1], CFG).labels))
    assert svc.stats.cancellations == 1


def test_cancel_follower_detaches_from_primary(rmat_g):
    g = rmat_g
    s = _sources(g, 1)[0]
    svc = _svc(g, slots=2)
    primary = svc.submit("g", "bfs", s)
    follower = svc.submit("g", "bfs", s)   # single-flight coalesced
    assert svc.cancel(follower)
    svc.run()
    assert svc.poll(primary).status == DONE
    assert svc.poll(follower).status == CANCELLED
    assert svc.poll(follower).result is None


def test_cancel_primary_promotes_follower(rmat_g):
    g = rmat_g
    s = _sources(g, 1)[0]
    svc = _svc(g, slots=2)
    primary = svc.submit("g", "bfs", s)
    follower = svc.submit("g", "bfs", s)
    assert svc.cancel(primary)
    svc.run()
    assert svc.poll(primary).status == CANCELLED
    qf = svc.poll(follower)          # heir computed the result itself
    assert qf.status == DONE
    assert np.array_equal(np.asarray(qf.result),
                          np.asarray(bfs(g, s, CFG).labels))


def test_cancelled_key_can_resubmit(rmat_g):
    g = rmat_g
    s = _sources(g, 1)[0]
    svc = _svc(g, slots=2)
    a = svc.submit("g", "bfs", s)
    assert svc.cancel(a)
    b = svc.submit("g", "bfs", s)    # must re-register, not coalesce
    svc.run()                        # onto the cancelled computation
    assert svc.poll(b).status == DONE
    assert np.array_equal(np.asarray(svc.poll(b).result),
                          np.asarray(bfs(g, s, CFG).labels))


# ---------------------------------------------------------------------------
# stats: percentile sentinels (the fix) + fleet aggregation
# ---------------------------------------------------------------------------

def test_percentile_sentinel_on_empty_window(rmat_g):
    svc = _svc(rmat_g)
    # regression: a fresh service used to be a NaN factory here; the
    # fleet aggregates percentiles across replicas, so empty windows
    # must read as 0.0 (no pressure), consistently at every percentile
    for p in (50, 95, 99):
        val = svc.stats.latency_percentile(p)
        assert val == 0.0 and isinstance(val, float)


def test_percentile_single_sample_window(rmat_g):
    g = rmat_g
    svc = _svc(g)
    svc.submit("g", "bfs", _sources(g, 1)[0])
    svc.run()
    assert len(svc.stats.rounds_in_system) == 1
    r = svc.stats.rounds_in_system[0]
    for p in (50, 95, 99):
        assert svc.stats.latency_percentile(p) == float(r)


def test_fleet_p95_finite_with_idle_replica(rmat_g):
    # one replica never serves anything; the aggregate must stay a
    # finite number, not NaN-poisoned by the idle replica
    fleet = _fleet(n=3, seed=9)
    fleet.register_graph("g", rmat_g)
    key_src = _sources(rmat_g, 1)[0]
    fleet.submit("g", "bfs", key_src)
    fleet.run()
    p95 = fleet.summary()["p95_rounds"]
    assert np.isfinite(p95) and p95 >= 0.0


# ---------------------------------------------------------------------------
# fleet end-to-end
# ---------------------------------------------------------------------------

def test_fleet_end_to_end_parity(rmat_g):
    g = rmat_g
    fleet, fqids, traffic = _drained_fleet(g, n_queries=24)
    for fqid, s in zip(fqids, traffic):
        rec = fleet.poll(fqid)
        assert rec.status == DONE
        assert np.array_equal(np.asarray(rec.result),
                              np.asarray(bfs(g, s, CFG).labels))
    summary = fleet.summary()
    assert summary["queries_served"] == len(fqids)
    assert summary["per_replica_load"] == (0, 0, 0)


def test_affinity_routes_repeats_to_owner(rmat_g):
    g = rmat_g
    fleet = _fleet(seed=7)
    fleet.register_graph("g", g)
    s = _sources(g, 1)[0]
    owner = rendezvous_order(("g", "bfs", s), 3)[0]
    first = fleet.submit("g", "bfs", s)
    fleet.run()
    repeat = fleet.submit("g", "bfs", s)
    fleet.run()
    assert fleet.poll(first).winner == owner
    rec = fleet.poll(repeat)
    assert rec.winner == owner and rec.from_cache
    assert fleet.summary()["fleet_hit_rate"] == 0.5


def test_affinity_off_is_pure_p2c(rmat_g):
    fleet, _, _ = _drained_fleet(rmat_g, affinity=False)
    reasons = {row.reason for row in fleet.trace.rows}
    assert "affinity" not in reasons and "spill" not in reasons


def test_feedback_controller_tightens_and_relaxes():
    cfg = RouterConfig(p95_target=10.0, hedge_after=8)
    ctl = FeedbackController(cfg)
    for _ in range(30):
        ctl.update(100.0)            # sustained SLO violation
    assert ctl.w_tail == cfg.w_tail * cfg.max_weight_gain
    assert ctl.hedge_after == cfg.min_hedge_after
    for _ in range(200):
        ctl.update(1.0)              # calm: decay back to defaults
    assert ctl.w_tail == pytest.approx(cfg.w_tail)
    assert ctl.hedge_after == cfg.hedge_after


def test_hedgeable_predicate():
    pol = HedgePolicy(max_hedges=1)
    rec = FleetQuery(fqid=0, graph_id="g", app="bfs", source=0,
                     submit_step=0)
    assert not hedgeable(rec, 3, 12, pol)      # too young
    assert hedgeable(rec, 12, 12, pol)
    rec.hedges = 1
    assert not hedgeable(rec, 20, 12, pol)     # budget spent
    rec.hedges = 0
    rec.status = DONE
    assert not hedgeable(rec, 20, 12, pol)     # already published
    assert not hedgeable(
        FleetQuery(fqid=1, graph_id="g", app="bfs", source=0),
        20, 12, HedgePolicy(enabled=False))


def test_fleet_on_devices(rmat_g):
    # replicas pinned round-robin across the host's jax devices keep
    # the same routing and the same results (single-device hosts just
    # pin everything to device 0)
    import jax
    devs = jax.devices()
    g = rmat_g
    fleet = Fleet(num_replicas=3, cfg=CFG, num_slots=4,
                  cache_capacity=0, seed=1, devices=devs)
    fleet.register_graph("g", g)
    srcs = _sources(g, 6, seed=2)
    fqids = [fleet.submit("g", "bfs", s) for s in srcs]
    fleet.run()
    for fqid, s in zip(fqids, srcs):
        rec = fleet.poll(fqid)
        assert np.array_equal(np.asarray(rec.result),
                              np.asarray(bfs(g, s, CFG).labels))
    assert replay(fleet.trace.rows) == []
