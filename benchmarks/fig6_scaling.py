"""Fig 6/10 analogue: multi-device scaling of D-IrGL(TWC) vs
D-IrGL(ALB) — BSP rounds over partitioned graphs, 1..8 devices, under
both sync substrates (``replicated`` all-reduce vs ``mirror``
boundary exchange, DESIGN.md section 6).

Besides the CSV rows, writes ``benchmarks/out/fig6_scaling.json`` with
per-round communication volume (``bytes_synced``, summed over devices)
so the perf trajectory tracks what actually crosses the interconnect,
not just wall clock.  Each row also carries ``mode`` (host vs fused
round loop, DESIGN.md section 11) and ``host_transfers`` — the number
of blocking device->host sync points the traversal performed (one per
round for the host loop, zero for the fused ``lax.while_loop``).

Re-execs itself with a forced host device count so the multi-device
run never contaminates the parent process's single-device state.
"""
from __future__ import annotations

import os
import subprocess
import sys

MAX_DEV = 8
OUT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "out", "fig6_scaling.json")


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{MAX_DEV}").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run([sys.executable, "-m", "benchmarks.fig6_scaling",
                        "--inner"], env=env, cwd=root,
                       capture_output=True, text=True, timeout=3600)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr[-3000:])
        raise RuntimeError("fig6 inner run failed")


def inner():
    import json
    import time
    from repro.core import graph as G
    from repro.core.partition import partition
    from repro.core import gluon
    from repro.core.balancer import BalancerConfig, host_transfer_count
    from .common import emit

    g = G.rmat(13, 16, seed=1)
    src = G.highest_out_degree_vertex(g)
    rows = []
    for ndev in [1, 2, 4, 8]:
        mesh = gluon.device_mesh(ndev)
        sg, meta = partition(g, ndev, "oec")
        for strat in ["twc", "alb"]:
            cfg = BalancerConfig(strategy=strat, threshold=1024)
            for sync in ["replicated", "mirror"]:
                # separate instrumented run: comm volume per round
                # (host mode only — fused + collect_stats is rejected)
                _, _, _, stats = gluon.sssp_distributed(
                    sg, mesh, src, cfg, max_rounds=200,
                    collect_stats=True, sync=sync, meta=meta)
                bytes_per_round = [
                    int(sum(st.bytes_synced for st in per_round))
                    for per_round in stats]
                total_bytes = sum(bytes_per_round)
                for mode in ["host", "fused"]:
                    # warmup (compile)
                    gluon.sssp_distributed(sg, mesh, src, cfg,
                                           max_rounds=200, sync=sync,
                                           meta=meta, mode=mode)
                    t_sync = host_transfer_count()
                    t0 = time.perf_counter()
                    labels, rounds, _ = gluon.sssp_distributed(
                        sg, mesh, src, cfg, max_rounds=200,
                        sync=sync, meta=meta, mode=mode)
                    secs = time.perf_counter() - t0
                    ht = host_transfer_count() - t_sync
                    emit(f"fig6/sssp/{strat}/gpus{ndev}/{sync}/{mode}",
                         secs,
                         f"rounds={rounds};bytes_total={total_bytes};"
                         f"ht={ht}")
                    rows.append(dict(
                        app="sssp", strategy=strat, num_devices=ndev,
                        sync=sync, mode=mode, seconds=secs,
                        rounds=rounds, host_transfers=ht,
                        bytes_synced_per_round=bytes_per_round,
                        bytes_synced_total=total_bytes,
                        replication_factor=meta.replication_factor))
    os.makedirs(os.path.dirname(OUT_JSON), exist_ok=True)
    with open(OUT_JSON, "w") as f:
        json.dump(dict(
            figure="fig6_scaling",
            graph=dict(kind="rmat", scale=13, edge_factor=16,
                       num_vertices=g.num_vertices,
                       num_edges=g.num_edges),
            replicated_baseline_bytes_per_round={
                str(d): g.num_vertices * 4 * d for d in [1, 2, 4, 8]},
            rows=rows), f, indent=2)
    print(f"# wrote {OUT_JSON}", flush=True)


if __name__ == "__main__":
    if "--inner" in sys.argv:
        inner()
    else:
        run()
