from .drivers import (bfs, sssp, cc, pagerank, kcore, bfs_batch,
                      sssp_batch, AppResult, relax_round, step_batch,
                      resume_loop, QUERY_APPS)

__all__ = ["bfs", "sssp", "cc", "pagerank", "kcore", "bfs_batch",
           "sssp_batch", "AppResult", "relax_round", "step_batch",
           "resume_loop", "QUERY_APPS"]
