"""Pure routing policy of the serving fleet (DESIGN.md section 13).

Every routing decision is a PURE function of a
:class:`DecisionInputs` record — loads, tail-risk scores, rendezvous
ranks, the sampled power-of-two pair — so any decision can be
re-derived offline from a recorded trace (:mod:`repro.serve.fleet.trace`)
and compared bitwise against the live run.  Three rules compose:

* **Cache-affinity (rendezvous hashing).**  Each key
  ``(graph_id, app, source)`` owns a deterministic preference order
  over replicas — highest-random-weight (HRW) hashing via blake2b, so
  the order is stable across processes and immune to
  ``PYTHONHASHSEED``.  Removing a replica remaps only the keys it
  owned; adding one steals ~1/N of the keyspace.  Routing repeats of
  a key to its affinity replica is what makes the per-replica LRU
  result caches effective.
* **Bounded-load redirection.**  The affinity replica is used only
  while its assigned load stays under the ceiling
  ``ceil(c * (total_load + 1) / n)`` (classic bounded-load consistent
  hashing); past it the query spills to the power-of-two choice, and
  if that too is over the ceiling, to the globally least-loaded
  replica — which is provably under the ceiling, so no executed
  assignment ever exceeds it.
* **Power-of-two-choices admission.**  Two distinct replicas are
  sampled (by the fleet's seeded generator — the PAIR is an input,
  not the randomness) and the lower tail-risk score wins; ties break
  to the lower replica id.  The score is
  ``load + w_tail * rounds_remaining + w_age * queue_head_age``
  (the ALPHA1 composite: queue depth, EWMA'd work left, head-of-line
  age), with the weights nudged by :class:`FeedbackController`
  against a p95 rounds-in-system target.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Optional, Sequence, Tuple

_HASH_BYTES = 8


def _hrw_weight(key_repr: str, replica: int) -> int:
    """Deterministic 64-bit HRW weight of (key, replica)."""
    h = hashlib.blake2b(f"{key_repr}|{replica}".encode(),
                        digest_size=_HASH_BYTES)
    return int.from_bytes(h.digest(), "big")


def rendezvous_order(key: tuple, num_replicas: int) -> Tuple[int, ...]:
    """Replica ids sorted best-affinity-first for ``key`` (highest
    blake2b HRW weight wins; ties — astronomically unlikely — break to
    the lower id).  ``order[0]`` is the key's affinity replica."""
    key_repr = repr(tuple(key))
    return tuple(sorted(range(num_replicas),
                        key=lambda r: (-_hrw_weight(key_repr, r), r)))


def load_ceiling(loads: Sequence[int], capacity_factor: float) -> int:
    """Bounded-load ceiling after admitting one more query:
    ``ceil(c * (total + 1) / n)``.  With ``c >= 1`` at least one
    replica (the least loaded) is always strictly under it."""
    total = sum(loads)
    return int(math.ceil(capacity_factor * (total + 1) / len(loads)))


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Static routing policy knobs (the adaptive weights start from
    these and are clamped around them)."""
    capacity_factor: float = 1.25   # c of the bounded-load ceiling
    affinity: bool = True           # False => pure P2C (the ablation
    #                                 pairing the hit-rate gate runs)
    w_tail: float = 1.0             # weight on rounds_remaining
    w_age: float = 0.5              # weight on queue_head_age
    p95_target: float = 50.0        # rounds-in-system SLO the
    #                                 feedback controller steers to
    hedge_after: int = 12           # fleet steps in system before a
    #                                 query becomes hedgeable
    min_hedge_after: int = 2        # controller floor for hedge_after
    max_weight_gain: float = 8.0    # controller clamp: weights stay in
    #                                 [initial, initial * gain]


@dataclasses.dataclass(frozen=True)
class DecisionInputs:
    """Everything a routing decision is a function of — recorded
    verbatim into the trace, so replay is exact by construction."""
    seq: int                        # trace sequence number
    fqid: int                       # fleet query id
    kind: str                       # "route" | "hedge"
    key: tuple                      # (graph_id, app, source)
    loads: Tuple[int, ...]          # assigned load per replica
    scores: Tuple[float, ...]       # tail-risk score per replica
    order: Tuple[int, ...]          # rendezvous order, best first
    pair: Tuple[int, ...]           # sampled P2C candidates (1 or 2)
    capacity_factor: float
    affinity: bool
    exclude: Tuple[int, ...] = ()   # replicas already holding the
    #                                 query (hedges never re-land on
    #                                 their origin)


def decide(inp: DecisionInputs) -> Tuple[int, str]:
    """The routing decision: ``(replica_id, reason)`` with reason in
    ``{"affinity", "spill", "p2c", "hedge"}``.  Pure and total over
    its inputs — the trace replayer calls exactly this function."""
    n = len(inp.loads)
    ceiling = load_ceiling(inp.loads, inp.capacity_factor)
    allowed = [r for r in range(n) if r not in inp.exclude]
    if inp.kind == "hedge":
        reason = "hedge"
    elif inp.affinity:
        aff = inp.order[0]
        if inp.loads[aff] + 1 <= ceiling:
            return aff, "affinity"
        reason = "spill"
    else:
        reason = "p2c"
    cand: Optional[int] = min(
        (r for r in inp.pair if r in allowed),
        key=lambda r: (inp.scores[r], r), default=None)
    if cand is None or inp.loads[cand] + 1 > ceiling:
        # bounded-load fallback: the least-loaded allowed replica is
        # at most the mean, hence strictly under the ceiling (always
        # true when nothing is excluded; hedges re-check the ceiling
        # before launching)
        cand = min(allowed, key=lambda r: (inp.loads[r], r))
    return cand, reason


class FeedbackController:
    """Nudges the live router weights against the p95 rounds-in-system
    target (DESIGN.md section 13).

    Above target: the score leans harder on the tail terms (spread
    away from backed-up replicas) and queries become hedgeable
    earlier.  Well below target (< half): decay back toward the
    configured defaults so the fleet does not stay over-corrected.
    Weights are clamped to ``[initial, initial * max_weight_gain]``
    and ``hedge_after`` to ``[min_hedge_after, initial]``, so the
    controller can never run away.
    """

    def __init__(self, cfg: RouterConfig) -> None:
        self.cfg = cfg
        self.w_tail = cfg.w_tail
        self.w_age = cfg.w_age
        self.hedge_after = cfg.hedge_after

    def update(self, p95: float) -> None:
        """One feedback step against the observed fleet-wide p95
        rounds-in-system (0.0 — the empty-window sentinel — reads as
        'no pressure')."""
        cfg = self.cfg
        if p95 > cfg.p95_target:
            self.w_tail = min(self.w_tail * 1.25,
                              cfg.w_tail * cfg.max_weight_gain)
            self.w_age = min(self.w_age * 1.25,
                             cfg.w_age * cfg.max_weight_gain)
            self.hedge_after = max(cfg.min_hedge_after,
                                   self.hedge_after - 1)
        elif p95 < 0.5 * cfg.p95_target:
            self.w_tail = max(self.w_tail * 0.9, cfg.w_tail)
            self.w_age = max(self.w_age * 0.9, cfg.w_age)
            self.hedge_after = min(cfg.hedge_after,
                                   self.hedge_after + 1)
