"""Operator algebra for vertex programs.

A vertex program round applies an *operator* along edges of active
vertices (Section 2.1 of the paper).  We factor an operator into:

* ``direction``: ``push`` (value flows src -> dst, scatter at dst) or
  ``pull`` (value gathered from the neighbour, scatter at the anchor),
* ``msg``: candidate from the propagated vertex value + edge weight,
* ``combine``: how candidates merge at the target label (``min``/``add``).

Operators are module-level singletons so jit caches key on identity.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True, eq=False)
class Operator:
    name: str
    direction: str                    # 'push' | 'pull'
    combine: str                      # 'min'  | 'add'
    msg: Callable                     # (value, weight) -> candidate
    uses_weight: bool = True


# sssp relaxation: dist[dst] = min(dist[dst], dist[src] + w)
SSSP_RELAX = Operator("sssp_relax", "push", "min",
                      lambda v, w: v + w)

# bfs: level[dst] = min(level[dst], level[src] + 1)
BFS_HOP = Operator("bfs_hop", "push", "min",
                   lambda v, w: v + 1, uses_weight=False)

# connected components (label propagation on symmetrized graph):
# comp[dst] = min(comp[dst], comp[src])
CC_MIN = Operator("cc_min", "push", "min",
                  lambda v, w: v, uses_weight=False)

# kcore: when a vertex dies, its (symmetrized) neighbours lose a degree
KCORE_DEC = Operator("kcore_dec", "push", "add",
                     lambda v, w: jnp.full_like(v, -1), uses_weight=False)

# pagerank (pull): acc[v] += contrib[u] for in-neighbours u; the per-
# vertex contribution rank[u]/outdeg[u] is precomputed as the value.
PR_PULL = Operator("pr_pull", "pull", "add",
                   lambda v, w: v, uses_weight=False)
