"""AdamW with global-norm clipping, built on plain pytrees.

Master weights and moments are f32; gradients may arrive bf16 from the
mixed-precision backward pass and are upcast at use.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # H2: bf16 model params + f32 master copies in the optimizer — the
    # FSDP all-gathers move half the bytes (gathers run on the bf16
    # params), at +2 bytes/param optimizer state.
    master_weights: bool = False


def adamw_init(params, master_weights: bool = False):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    state = {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, m, g, mu, nu):
        # m: f32 master (== p when master_weights is off)
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        # decoupled weight decay (skip 1-D params: norms/biases)
        if p.ndim > 1:
            delta = delta + cfg.weight_decay * m.astype(jnp.float32)
        new_m = m.astype(jnp.float32) - lr * delta
        return new_m.astype(p.dtype), new_m, mu, nu

    masters = state.get("master", params)
    flat_p, treedef = jax.tree.flatten(params)
    flat_m = treedef.flatten_up_to(masters)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, m, g, mu, nu)
           for p, m, g, mu, nu in zip(flat_p, flat_m, flat_g, flat_mu,
                                      flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[2] for o in out])
    new_nu = treedef.unflatten([o[3] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    if "master" in state:
        new_state["master"] = treedef.unflatten([o[1] for o in out])
    return new_p, new_state, gnorm
