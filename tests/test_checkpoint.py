"""Elastic checkpoint restore (DESIGN.md section 4).

Checkpoints are mesh-agnostic: arrays are saved with their GLOBAL
logical shape, so a job restarted with a different device count
re-shards on restore.  These tests save under a 4-device mesh and
restore under 2- and 1-device meshes (subsets of the same forced-host
device pool), asserting the global values round-trip bitwise and the
restored arrays land with the new sharding.  Crash-safety (a
``step_<n>/`` directory without a manifest is ignored) is covered
host-only, tier-1.
"""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import pytest

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step)

NDEV = 4
multidevice = pytest.mark.skipif(
    len(jax.devices()) < NDEV,
    reason=f"needs {NDEV} devices (CI sets "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={NDEV})")


def _mesh(n):
    return Mesh(np.asarray(jax.devices()[:n]), ("dev",))


def _sharded_state(mesh):
    """A training-like pytree with a dev-sharded leaf and a replicated
    one."""
    w = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
    step_scale = jnp.float32(0.5)
    return {
        "w": jax.device_put(w, NamedSharding(mesh, P("dev", None))),
        "scale": jax.device_put(step_scale, NamedSharding(mesh, P())),
    }


@multidevice
@pytest.mark.parametrize("restore_ndev", [1, 2])
def test_elastic_restore_across_mesh_sizes(tmp_path, restore_ndev):
    save_mesh = _mesh(NDEV)
    state = _sharded_state(save_mesh)
    save_checkpoint(str(tmp_path), 11, state)

    # the checkpoint records GLOBAL shapes, not per-device shards
    with open(os.path.join(str(tmp_path), "step_00000011",
                           "MANIFEST.json")) as f:
        manifest = json.load(f)
    assert manifest["shapes"]["w"] == [8, 16]
    assert manifest["shapes"]["scale"] == []

    # restart with fewer devices: same template shapes, new sharding
    restore_mesh = _mesh(restore_ndev)
    template = jax.eval_shape(lambda: {
        "w": jnp.zeros((8, 16), jnp.float32),
        "scale": jnp.zeros((), jnp.float32)})
    restored, man = restore_checkpoint(str(tmp_path), 11, template)
    assert man["step"] == 11
    resharded = {
        "w": jax.device_put(
            restored["w"], NamedSharding(restore_mesh, P("dev", None))),
        "scale": jax.device_put(
            restored["scale"], NamedSharding(restore_mesh, P())),
    }
    np.testing.assert_array_equal(np.asarray(resharded["w"]),
                                  np.asarray(state["w"]))
    assert float(resharded["scale"]) == 0.5
    assert len(resharded["w"].sharding.device_set) == restore_ndev
    # and the re-sharded state is usable on the new mesh
    out = jax.jit(lambda s: s["w"].sum() * s["scale"])(resharded)
    assert float(out) == float(np.asarray(state["w"]).sum() * 0.5)


@multidevice
def test_elastic_restore_round_trips_through_growth(tmp_path):
    """4 -> 2 -> 4 devices: a second save from the shrunk mesh restores
    bitwise on the original mesh size."""
    state4 = _sharded_state(_mesh(NDEV))
    save_checkpoint(str(tmp_path), 1, state4)
    template = jax.eval_shape(lambda: {
        "w": jnp.zeros((8, 16), jnp.float32),
        "scale": jnp.zeros((), jnp.float32)})
    mid, _ = restore_checkpoint(str(tmp_path), 1, template)
    mesh2 = _mesh(2)
    mid = jax.tree.map(
        lambda x: jax.device_put(jnp.asarray(x), NamedSharding(
            mesh2, P("dev", None) if np.ndim(x) == 2 else P())), mid)
    save_checkpoint(str(tmp_path), 2, mid)
    back, _ = restore_checkpoint(str(tmp_path), 2, template)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(state4["w"]))


def test_manifestless_step_dir_ignored(tmp_path):
    """A ``step_<n>/`` directory without MANIFEST.json is an
    incomplete (crashed) write: ``latest_step`` must skip it."""
    tree = {"a": np.arange(4)}
    save_checkpoint(str(tmp_path), 5, tree)
    # a later, crashed write: directory + shard present, no manifest
    crashed = os.path.join(str(tmp_path), "step_00000009")
    os.makedirs(crashed)
    np.savez(os.path.join(crashed, "shard_0.npz"), a=np.arange(4))
    assert latest_step(str(tmp_path)) == 5
    restored, _ = restore_checkpoint(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_template_shape_mismatch_rejected(tmp_path):
    tree = {"a": np.arange(4)}
    save_checkpoint(str(tmp_path), 1, tree)
    with pytest.raises(AssertionError, match="ckpt"):
        restore_checkpoint(str(tmp_path), 1, {"a": np.arange(8)})
