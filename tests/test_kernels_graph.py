"""Per-kernel allclose vs ref.py oracles: shape/dtype sweeps + hypothesis."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import edge_lb, twc_gather, ref


def _mk_huge(rng, h, max_deg, dtype):
    deg = jnp.asarray(rng.integers(0, max_deg, h).astype(np.int32))
    start_e = jnp.cumsum(deg) - deg
    row = jnp.asarray(rng.integers(0, 1 << 20, h).astype(np.int32))
    val = jnp.asarray(rng.integers(0, 1 << 10, h).astype(dtype))
    return deg, start_e, row, val


@pytest.mark.parametrize("h", [8, 64, 256, 1024])
@pytest.mark.parametrize("distribution", ["cyclic", "blocked"])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_edge_lb_matches_ref(h, distribution, dtype):
    rng = np.random.default_rng(h)
    deg, start_e, row, val = _mk_huge(rng, h, 300, dtype)
    total = jnp.sum(deg)
    k = edge_lb.edge_lb_map(start_e, row, val, total, int(total),
                            tile_edges=2048, distribution=distribution)
    r = ref.edge_lb_map_ref(start_e, row, val, total, int(total),
                            tile_edges=2048, distribution=distribution)
    m = np.asarray(r[3])
    np.testing.assert_array_equal(np.asarray(k[3]), m)
    for a, b in zip(k[:3], r[:3]):
        np.testing.assert_array_equal(np.asarray(a)[m], np.asarray(b)[m])


@pytest.mark.parametrize("distribution", ["cyclic", "blocked"])
def test_edge_lb_full_coverage(distribution):
    """Every edge of every huge vertex appears exactly once (bijection
    property of the distribution permutation)."""
    rng = np.random.default_rng(7)
    deg, start_e, row, val = _mk_huge(rng, 128, 200, np.int32)
    total = jnp.sum(deg)
    ge, j, v, m = edge_lb.edge_lb_map(start_e, row, val, total, int(total),
                                      distribution=distribution)
    got = np.sort(np.asarray(ge)[np.asarray(m)])
    want = np.sort(np.concatenate(
        [np.arange(r, r + d)
         for r, d in zip(np.asarray(row), np.asarray(deg))]))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("width", [8, 128, 256, 1024])
@pytest.mark.parametrize("chunk", [0, 1])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_twc_bin_matches_ref(width, chunk, dtype):
    if chunk > 0 and width % 128:
        pytest.skip("chunked bins are 128-aligned by config")
    rng = np.random.default_rng(width + chunk)
    b = 53
    vidx = jnp.asarray(rng.integers(0, 4000, b).astype(np.int32))
    deg = jnp.asarray(rng.integers(0, (chunk + 1) * width + 1,
                                   b).astype(np.int32))
    row = jnp.asarray(rng.integers(0, 1 << 20, b).astype(np.int32))
    val = jnp.asarray(rng.integers(0, 1 << 10, b).astype(dtype))
    k = twc_gather.twc_bin_map(vidx, deg, row, val, width=width,
                               chunk=chunk, sentinel=1 << 22)
    r = ref.twc_bin_map_ref(vidx, deg, row, val, width=width, chunk=chunk,
                            sentinel=1 << 22)
    np.testing.assert_array_equal(np.asarray(k[3]), np.asarray(r[3]))
    m = np.asarray(r[3])
    for a, b_ in zip(k[:3], r[:3]):
        np.testing.assert_array_equal(np.asarray(a)[m], np.asarray(b_)[m])


# ---------------- property tests ----------------

@settings(max_examples=25, deadline=None)
@given(
    degs=st.lists(st.integers(0, 64), min_size=1, max_size=64),
    dist=st.sampled_from(["cyclic", "blocked"]),
)
def test_edge_lb_searchsorted_property(degs, dist):
    """Property: the kernel's (slot, graph_e) mapping inverts the prefix
    sum — for every emitted edge, start_e[j] <= eid < start_e[j]+deg[j]."""
    deg = jnp.asarray(np.asarray(degs, np.int32))
    start_e = jnp.cumsum(deg) - deg
    row = start_e  # rows laid out consecutively
    val = jnp.arange(len(degs), dtype=jnp.int32)
    total = jnp.sum(deg)
    if int(total) == 0:
        return
    ge, j, v, m = edge_lb.edge_lb_map(start_e, row, val, total, int(total),
                                      distribution=dist)
    ge, j, m = np.asarray(ge), np.asarray(j), np.asarray(m)
    sa, da = np.asarray(start_e), np.asarray(deg)
    assert (ge[m] >= sa[j[m]]).all()
    assert (ge[m] < sa[j[m]] + da[j[m]]).all()
    # values identify the slot
    assert (np.asarray(v)[m] == j[m]).all()


@settings(max_examples=25, deadline=None)
@given(
    degs=st.lists(st.integers(0, 40), min_size=1, max_size=48),
    width=st.sampled_from([8, 128]),
)
def test_twc_mask_property(degs, width):
    """Property: bin expansion emits exactly min(deg, width) edges/vertex."""
    b = len(degs)
    deg = jnp.asarray(np.asarray(degs, np.int32))
    vidx = jnp.arange(b, dtype=jnp.int32)
    row = jnp.zeros(b, jnp.int32)
    val = jnp.zeros(b, jnp.int32)
    ge, anchor, v, m = twc_gather.twc_bin_map(vidx, deg, row, val,
                                              width=width, sentinel=b + 1)
    per_vertex = np.asarray(m)[:b].sum(axis=1)
    np.testing.assert_array_equal(per_vertex,
                                  np.minimum(np.asarray(degs), width))


def test_cyclic_distribution_lane_locality():
    """Fig 4 structural claim: cyclic keeps each 128-lane group's
    binary searches within ~1 source slot; blocked diverges."""
    rng = np.random.default_rng(11)
    h = 64
    deg = jnp.asarray(rng.integers(200, 2000, h).astype(np.int32))
    start_e = jnp.cumsum(deg) - deg
    row = start_e
    val = jnp.zeros(h, jnp.int32)
    total = jnp.sum(deg)
    spans = {}
    for dist in ["cyclic", "blocked"]:
        ge, j, v, m = edge_lb.edge_lb_map(start_e, row, val, total,
                                          int(total), distribution=dist)
        jj = np.asarray(j)[np.asarray(m)]
        n = (len(jj) // 128) * 128
        groups = jj[:n].reshape(-1, 128)
        spans[dist] = float((groups.max(1) - groups.min(1) + 1).mean())
    assert spans["cyclic"] < 3.0
    assert spans["blocked"] > 5 * spans["cyclic"]
