"""``jit-purity``: traced functions must be pure and trace-stable.

Inside a function handed to ``jax.jit`` (any of the binding forms in
:mod:`repro.analysis.astutil`) or used as a ``pallas_call`` kernel:

* Python ``if``/``while``/ternaries may not branch on traced values —
  a non-static parameter or a local derived from one or from a
  ``jnp`` expression.  Branching on ``static_argnames`` parameters,
  ``x.ndim``/``x.shape``/``x.dtype`` metadata, or ``x is None`` is
  fine (all static at trace time).
* ``print(...)`` fires once per trace, not per call — use
  ``jax.debug.print`` if output is really wanted.
* Mutating a module-level name (or declaring ``global``) bakes a
  trace-time side effect into a supposedly pure function.
* Wall-clock / RNG calls (``time.*``, ``datetime.*``, ``random.*``,
  ``np.random.*``, ``uuid`` ...) are trace-time constants: the jitted
  function silently reuses the first value forever.
"""
from __future__ import annotations

import ast
from typing import List, Set

from .. import astutil
from ..findings import Finding
from ..registry import Rule, register_rule

RULE_ID = "jit-purity"

_NONDET_EXACT = {
    "time.time", "time.perf_counter", "time.monotonic",
    "time.process_time", "time.time_ns", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "os.urandom", "uuid.uuid1", "uuid.uuid4",
}
_NONDET_PREFIX = ("random.", "np.random.", "numpy.random.")


def _traced_locals(fn: ast.AST, traced_params: Set[str]) -> Set[str]:
    """Locals derived from traced params or jnp expressions
    (flow-insensitive fixpoint, includes nested defs)."""
    traced = set(traced_params)
    assigns = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            assigns.append((node.targets, node.value))
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)) \
                and node.value is not None:
            assigns.append(([node.target], node.value))
    for _ in range(4):
        changed = False
        for targets, value in assigns:
            if astutil.contains_jnp(value) or \
                    astutil.references_names(value, traced):
                for t in targets:
                    for sub in ast.walk(t):
                        if isinstance(sub, ast.Name) \
                                and sub.id not in traced:
                            traced.add(sub.id)
                            changed = True
        if not changed:
            break
    return traced


def _test_is_traced(test: ast.AST, traced: Set[str]) -> bool:
    if astutil.is_none_comparison(test):
        return False
    return astutil.references_names(test, traced)


def _check_fn(ctx, fn, fname, statics, module_names, out) -> None:
    params = set(astutil.param_names(fn))
    traced_params = params - set(statics)
    traced = _traced_locals(fn, traced_params)
    local_names = params | astutil.assigned_names(fn)
    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            kw = "while" if isinstance(node, ast.While) else "if"
            if _test_is_traced(node.test, traced):
                out.append(ctx.finding(
                    node, RULE_ID,
                    f"Python `{kw}` on a traced value inside jitted "
                    f"`{fname}` — use lax.cond/lax.while_loop/"
                    f"jnp.where, or make the argument static"))
        elif isinstance(node, ast.IfExp):
            if _test_is_traced(node.test, traced):
                out.append(ctx.finding(
                    node, RULE_ID,
                    f"ternary on a traced value inside jitted "
                    f"`{fname}` — use jnp.where/lax.cond"))
        elif isinstance(node, ast.Call):
            fd = astutil.dotted(node.func) or ""
            if fd == "print":
                out.append(ctx.finding(
                    node, RULE_ID,
                    f"print() inside jitted `{fname}` fires at trace "
                    f"time only — use jax.debug.print"))
            elif fd in _NONDET_EXACT or \
                    fd.startswith(_NONDET_PREFIX):
                out.append(ctx.finding(
                    node, RULE_ID,
                    f"nondeterministic call {fd}() inside jitted "
                    f"`{fname}` is frozen at trace time"))
        elif isinstance(node, ast.Global):
            out.append(ctx.finding(
                node, RULE_ID,
                f"`global` inside jitted `{fname}`: trace-time side "
                f"effect on module state"))
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                root = astutil.root_name(t)
                if (root is not None and root in module_names
                        and root not in local_names
                        and not isinstance(t, ast.Name)):
                    out.append(ctx.finding(
                        node, RULE_ID,
                        f"mutation of module-level `{root}` inside "
                        f"jitted `{fname}`: trace-time side effect"))


def check(ctx) -> List[Finding]:
    """Run the jit-purity pass over one file."""
    out: List[Finding] = []
    module_names = astutil.module_level_names(ctx.tree)
    seen = set()
    for b in ctx.jit_bindings:
        if b.func is None or id(b.func) in seen:
            continue
        seen.add(id(b.func))
        if b.static_names is None:
            continue  # non-literal static_argnames: cannot classify
        _check_fn(ctx, b.func, b.func_name or b.func.name,
                  b.static_names, module_names, out)
    return out


register_rule(Rule(
    id=RULE_ID,
    description="no Python control flow on tracers, print, global "
                "mutation, or wall-clock/RNG calls inside "
                "jax.jit/pallas_call functions",
    check=check,
    relaxed=True,
))
