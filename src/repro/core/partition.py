"""CuSP-analog graph partitioner (OEC / IEC / CVC policies).

Produces, for D devices, D edge-disjoint local CSR graphs over the
*global* vertex id space, stacked into one [D, ...] pytree suitable for
``shard_map``.  Labels are kept replicated per device (every vertex is
a mirror everywhere); the Gluon-analog sync (gluon.py) reduces them
with the operator's combiner after each BSP round.  This is the
"communication-heaviest but simplest" point in Gluon's design space and
is sufficient to reproduce the paper's BSP behaviour; the partition
policy controls *which edges* (and hence which compute) land on each
device, exactly the role OEC/IEC/CVC play in the paper's Figure 9.

* OEC: vertices -> D contiguous ranges balanced by out-degree; a device
  owns all out-edges of its vertices.
* IEC: same, but balanced by in-degree; a device owns all in-edges of
  its vertex range (edges are assigned by destination).
* CVC: cartesian vertex cut; edge (u,v) -> device grid cell
  (row(u), col(v)) with a near-square device grid.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from .graph import Graph


def _ranges_balanced(weights: np.ndarray, parts: int) -> np.ndarray:
    """Contiguous ranges with ~equal total weight. Returns bounds[D+1]."""
    total = int(weights.sum())
    csum = np.concatenate([[0], np.cumsum(weights)])
    targets = (np.arange(1, parts) * total) // parts
    cuts = np.searchsorted(csum, targets, side="left")
    return np.concatenate([[0], cuts, [len(weights)]]).astype(np.int64)


def _stack_local_graphs(edge_lists, num_vertices: int) -> Graph:
    """Build per-device CSR over global vid space, pad E, stack."""
    from .graph import from_edge_list
    locs = [from_edge_list(s, d, num_vertices, weights=w, dedup=False)
            for (s, d, w) in edge_lists]
    emax = max(g.num_edges for g in locs)
    emax = max(emax, 1)
    rows, cols, ws = [], [], []
    for g in locs:
        pad = emax - g.num_edges
        rows.append(np.asarray(g.row_ptr))
        cols.append(np.pad(np.asarray(g.col_idx), (0, pad)))
        ws.append(np.pad(np.asarray(g.edge_w), (0, pad),
                         constant_values=np.int32(1 << 30)))
    return Graph(row_ptr=jnp.asarray(np.stack(rows)),
                 col_idx=jnp.asarray(np.stack(cols)),
                 edge_w=jnp.asarray(np.stack(ws)))


def partition(g: Graph, num_devices: int, policy: str = "oec") -> Graph:
    """Partition ``g``; returns a stacked Graph with leading dim D."""
    rp = np.asarray(g.row_ptr).astype(np.int64)
    ci = np.asarray(g.col_idx).astype(np.int64)
    w = np.asarray(g.edge_w)
    n = g.num_vertices
    src = np.repeat(np.arange(n, dtype=np.int64), rp[1:] - rp[:-1])
    outdeg = rp[1:] - rp[:-1]

    if policy == "oec":
        bounds = _ranges_balanced(outdeg, num_devices)
        owner = np.searchsorted(bounds, src, side="right") - 1
    elif policy == "iec":
        indeg = np.bincount(ci, minlength=n)
        bounds = _ranges_balanced(indeg, num_devices)
        owner = np.searchsorted(bounds, ci, side="right") - 1
    elif policy == "cvc":
        pr = int(math.sqrt(num_devices))
        while num_devices % pr:
            pr -= 1
        pc = num_devices // pr
        rb = _ranges_balanced(outdeg, pr)
        cb = _ranges_balanced(np.bincount(ci, minlength=n), pc)
        r = np.searchsorted(rb, src, side="right") - 1
        c = np.searchsorted(cb, ci, side="right") - 1
        owner = r * pc + c
    else:
        raise ValueError(policy)

    edge_lists = []
    for d in range(num_devices):
        sel = owner == d
        edge_lists.append((src[sel], ci[sel], w[sel]))
    return _stack_local_graphs(edge_lists, n)


def partition_stats(stacked: Graph) -> dict:
    rp = np.asarray(stacked.row_ptr)
    local_edges = rp[:, -1]
    return dict(edges_per_device=local_edges.tolist(),
                imbalance=float(local_edges.max()
                                / max(local_edges.mean(), 1.0)))
