"""Continuous-batching query service over the ALB round loop
(DESIGN.md section 8).

Public surface: :class:`QueryService` (the engine), plus the pieces it
composes — :class:`QueryQueue`/:class:`Query`, :class:`Scheduler`,
:class:`ResultCache`, :class:`ServiceStats` — each usable standalone.
"""
from .queue import (Query, QueryQueue, QUEUED, RUNNING, DONE,
                    CANCELLED)
from .scheduler import Scheduler, SlotView, Decision
from .cache import ResultCache
from .stats import ServiceStats
from .engine import QueryService

__all__ = ["QueryService", "Query", "QueryQueue", "Scheduler",
           "SlotView", "Decision", "ResultCache", "ServiceStats",
           "QUEUED", "RUNNING", "DONE", "CANCELLED"]
