"""Batched serving example: prefill a batch of prompts, then decode
tokens autoregressively with the KV/SSM cache — the serve-side twin of
train_lm.py, exercised on two architecture families (dense + SSM).

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402

from repro.configs import get_smoke_config     # noqa: E402
from repro.models import transformer as T     # noqa: E402

B, PROMPT, GEN = 4, 48, 16

for arch in ["llama3-8b", "mamba2-2.7b"]:
    cfg = get_smoke_config(arch)
    params = T.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT),
                                 0, cfg.vocab_size, jnp.int32)
    cache = T.zeros_cache(cfg, B, PROMPT + GEN)

    prefill = jax.jit(lambda p, t, c: T.prefill(p, cfg, t, c))
    decode = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    for _ in range(GEN - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
            .astype(jnp.int32)
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"{arch}: batch={B} prompt={PROMPT} generated={GEN} tokens "
          f"in {dt * 1e3:.0f} ms (incl. compile); "
          f"sample: {out[0, :8].tolist()}")
