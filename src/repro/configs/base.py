"""Model / run configuration schema for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 64
    top_k: int = 6
    num_shared_experts: int = 2
    d_expert: int = 1408          # per-expert FFN hidden
    capacity_factor: float = 1.25
    # GShard-style grouped dispatch: positions/capacity computed within
    # each of `dispatch_groups` token groups (aligned to the data axis)
    # so the position prefix-sum never crosses shard boundaries.  1 =
    # single global group.
    dispatch_groups: int = 1
    # ALB-adaptive dispatch (DESIGN.md section 5): when the router's load
    # histogram exceeds the threshold, overflow tokens are re-dealt to
    # their next-best expert via the prefix-sum renumbering.
    adaptive: bool = True
    router_aux_weight: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None            # default d_model // num_heads
    attention: str = "gqa"                    # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"                         # silu (swiglu) | gelu
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): every `attn_every` ssm blocks, apply the *shared*
    # attention block (single weight set, zamba2's key trick)
    attn_every: int = 0
    # modality frontend stub: prepended embedding prefix [B, prefix_len, D]
    prefix_len: int = 0
    num_codebooks: int = 1                    # musicgen: 4 EnCodec streams
    sub_quadratic: bool = False               # may run long_500k
    max_seq_len: int = 524_288

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables are padded to a 128 multiple so the
        vocab dim shards evenly on any mesh axis (MaxText-style)."""
        return -(-self.vocab_size // 128) * 128

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable_shapes(cfg: ModelConfig):
    """long_500k only for sub-quadratic archs (assignment skip rule)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return out
