"""Fault-tolerant checkpointing.

Design for 1000+ nodes (DESIGN.md section 4):
* step-granular directories ``step_<n>/``, one npz per host shard,
* a ``MANIFEST.json`` written LAST with an atomic rename — a directory
  without a manifest is incomplete and ignored by restore (crash-safe),
* async writer thread so the train loop never blocks on disk,
* elastic restore: arrays are saved with their GLOBAL logical shape;
  restore re-shards to whatever mesh the restarted job has (device
  count may differ — checkpoints are mesh-agnostic).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree, extra: dict | None
                    = None) -> str:
    """Synchronous save; returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)                 # atomic publish
    return path


def latest_step(directory: str) -> int | None:
    """Newest COMPLETE checkpoint (manifest present)."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        if not os.path.exists(os.path.join(directory, name,
                                           "MANIFEST.json")):
            continue
        step = int(name.split("_")[1])
        best = step if best is None else max(best, step)
    return best


def restore_checkpoint(directory: str, step: int, tree_template):
    """Restore into the structure of ``tree_template`` (shapes/dtypes
    may come from ``jax.eval_shape`` — elastic re-shard happens when the
    caller ``device_put``s with its own shardings)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    flat = jax.tree_util.tree_flatten_with_path(tree_template)
    leaves = []
    for pathk, leaf in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pathk)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"{key}: ckpt {arr.shape} vs template {leaf.shape}"
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(flat[1], leaves), manifest


class AsyncCheckpointer:
    """Background writer: ``submit`` returns immediately; the previous
    write is awaited first so at most one write is in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        self._err = None

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, extra = item
            try:
                save_checkpoint(self.directory, step, tree, extra)
                self._gc()
            except Exception as e:     # surfaced on next submit/close
                self._err = e

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def submit(self, step: int, tree, extra: dict | None = None):
        if self._err:
            raise self._err
        # materialize on host before handing to the thread
        host_tree = jax.tree.map(np.asarray, tree)
        self._q.put((step, host_tree, extra))

    def close(self):
        self._q.put(None)
        self._thread.join()
        if self._err:
            raise self._err
