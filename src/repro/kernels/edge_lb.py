"""Pallas TPU kernel for the LB executor (the paper's SSSP_LB kernel).

The kernel implements the edge-balanced renumbering: given the huge
vertices' exclusive degree prefix sum (``start_e``), their CSR row
starts and propagated values, every grid step processes one tile of
global edge ids and recovers, per edge,

    j        = searchsorted(start_e, eid)      (binary search, Fig. 4)
    graph_e  = row_start[j] + (eid - start_e[j])
    src, val = vertex id and propagated value of slot j

GPU -> TPU mapping: one grid step = one "thread block"; the (R, 128)
edge tile = the block's lanes.  The ``cyclic`` distribution gives every
grid step a *contiguous* run of edge ids, so neighbouring lanes binary-
search for neighbouring ids (same root->leaf path: VPU-uniform, one
VMEM line of ``start_e`` per step) and the subsequent ``col_idx``
gathers are coalesced.  ``blocked`` strides lane ids by ``w_per``,
destroying both properties — the paper's Figure 4/8 comparison.

Edge-id enumeration contract (shared with the XLA ``_lb_pass`` in
core/balancer.py): ``w_per = ceil(ecap / num_tiles)`` and the blocked
permutation is a bijection of exactly ``span = w_per * num_tiles`` ids.
The kernel grid covers ``span`` rounded up to the tile size; positions
past ``span`` are masked out *before* the permutation is applied, so
blocked mode can neither miss nor double-process an edge regardless of
how ``num_tiles`` divides the padded extent (double-processing would
corrupt add-combine operators).

The prefix/row/value arrays of the huge bin are small (a few thousand
entries at most: huge vertices are rare by definition), so each grid
step keeps them whole in VMEM — the TPU realization of the paper's
"binary search path stays in cache" argument.

The heavy irregular traffic (col_idx[graph_e] gathers from HBM and the
scatter-min into the label array) is left to XLA's native gather /
scatter-min, which the TPU does well; the kernel produces the
(graph_e, src, val) triples.  Validated with interpret=True vs ref.py.

Batched queries (DESIGN.md section 7): the mapping is a pure function
of the union frontier's huge bin — (graph_e, slot, mask) are shared by
every query in a batch — so ``ops.edge_lb_apply*`` launch this kernel
ONCE per round regardless of the batch size and re-gather per-query
values in the XLA epilogue; the kernel's ``val`` output then carries a
single query's view and is ignored there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(start_ref, row_ref, val_ref, total_ref,
            ge_ref, src_ref, val_out_ref, msk_ref,
            *, tile_r: int, distribution: str, w_per: int,
            num_tiles: int, span: int, h: int):
    i = pl.program_id(0)
    tile = tile_r * 128
    # ---- edge ids for this tile -------------------------------------
    lin = (jax.lax.broadcasted_iota(jnp.int32, (tile_r, 128), 0) * 128
           + jax.lax.broadcasted_iota(jnp.int32, (tile_r, 128), 1))
    eid0 = i * tile + lin
    enum_ok = eid0 < span          # bijection domain of the permutation
    if distribution == "blocked":
        eid = (eid0 % num_tiles) * w_per + eid0 // num_tiles
    else:  # cyclic: contiguous ids per tile (lane-major)
        eid = eid0
    total = total_ref[0, 0]
    emask = enum_ok & (eid < total)
    eid_c = jnp.where(emask, eid, 0)

    start_e = start_ref[0, :]                      # [H] whole, in VMEM
    row_start = row_ref[0, :]
    hval = val_ref[0, :]

    # ---- vectorized binary search (searchsorted right - 1) ----------
    # fixed trip count log2(H); all lanes walk the same depth
    lo = jnp.zeros_like(eid_c)
    hi = jnp.full_like(eid_c, h)                   # search in [lo, hi)
    steps = max(1, (h - 1).bit_length())
    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        pivot = jnp.take(start_e, mid)
        go_right = pivot <= eid_c
        return (jnp.where(go_right, mid + 1, lo),
                jnp.where(go_right, hi, mid))
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    j = jnp.clip(lo - 1, 0, h - 1)

    ge_ref[...] = jnp.where(emask,
                            jnp.take(row_start, j)
                            + (eid_c - jnp.take(start_e, j)), 0)
    src_ref[...] = j
    val_out_ref[...] = jnp.take(hval, j)
    msk_ref[...] = emask.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_enum", "tile_edges", "distribution", "num_tiles",
                     "interpret"))
def edge_lb_map(start_e: jax.Array, row_start: jax.Array, hval: jax.Array,
                total_edges: jax.Array, n_enum: int | None = None, *,
                tile_edges: int = 2048, distribution: str = "cyclic",
                num_tiles: int = 64, interpret: bool = True):
    """Run the LB mapping kernel over ``n_enum`` edge ids.

    Returns (graph_e, slot_j, src_val, mask) flat arrays of length
    ``ceil(w_per * num_tiles / tile_edges) * tile_edges`` where
    ``w_per = ceil(n_enum / num_tiles)`` — the enumeration span padded
    to the kernel tile size.
    """
    h = start_e.shape[0]
    if n_enum is None:
        n_enum = h  # caller really should pass the edge span
    tile_r = tile_edges // 128
    assert tile_edges % 128 == 0
    w_per = -(-n_enum // num_tiles)
    span = w_per * num_tiles          # exact bijection domain
    n_pad = -(-span // tile_edges) * tile_edges
    grid = n_pad // tile_edges

    out_shape = [
        jax.ShapeDtypeStruct((grid * tile_r, 128), jnp.int32),  # graph_e
        jax.ShapeDtypeStruct((grid * tile_r, 128), jnp.int32),  # slot j
        jax.ShapeDtypeStruct((grid * tile_r, 128), hval.dtype),  # value
        jax.ShapeDtypeStruct((grid * tile_r, 128), jnp.int32),  # mask
    ]
    kern = functools.partial(_kernel, tile_r=tile_r,
                             distribution=distribution, w_per=w_per,
                             num_tiles=num_tiles, span=span, h=h)
    full = pl.BlockSpec((1, h), lambda i: (0, 0))
    outs = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[full, full, full, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((tile_r, 128), lambda i: (i, 0))] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(start_e[None, :], row_start[None, :], hval[None, :],
      total_edges.reshape(1, 1))
    ge, j, val, msk = (o.reshape(-1) for o in outs)
    return ge, j, val, msk.astype(bool)
