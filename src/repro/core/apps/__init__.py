from .drivers import (bfs, sssp, cc, pagerank, kcore, bfs_batch,
                      sssp_batch, AppResult)

__all__ = ["bfs", "sssp", "cc", "pagerank", "kcore", "bfs_batch",
           "sssp_batch", "AppResult"]
