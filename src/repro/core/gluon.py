"""Gluon-analog distributed BSP runtime over shard_map.

Execution model (paper Section 2.1 / 5, DESIGN.md section 4): each
device computes a round on its local partition with the full ALB
machinery, then participates in a global synchronization that
reconciles vertex labels with the operator's combiner (min for
bfs/sssp/cc, add for pr/kcore deltas).

Two sync substrates are available (``sync=`` on every driver):

* ``"replicated"`` — every vertex mirrored everywhere; sync is a single
  ``pmin``/``psum`` over the ``dev`` mesh axis — one fused all-reduce
  per round.  Communication-heaviest but simplest; kept as the parity
  baseline.
* ``"mirror"`` — the master/mirror substrate (DESIGN.md section 6):
  labels live per device, every vertex has one master
  (``PartitionMeta.master_bounds``), and each round runs Gluon's
  reduce-broadcast pair over the *boundary only* — a dirty-masked
  reduce-to-master followed by a broadcast-to-mirrors, both built from
  gathers over the padded mirror index lists plus ``lax.ppermute``
  rings over the ``dev`` axis.  Only labels touched this round (the
  jit-safe dirty bitvector out of ``relax_spmd``) carry payload;
  ``RoundStatsDev.bytes_synced`` / ``mirrors_synced`` count them.

The per-device round is the fully-jit ``relax_spmd`` variant, whose
``lax.cond`` inspector skips the LB executor's work on devices whose
local partition has no huge frontier vertex this round — the paper's
adaptivity, per device.  ``relax_spmd`` dispatches through the executor
registry (DESIGN.md section 3), so ``BalancerConfig.use_pallas=True``
runs the Pallas LB/TWC mapping kernels *inside* ``shard_map``, and
``collect_stats=True`` threads jit-safe per-device ``RoundStatsDev``
through the same ``shard_map`` boundary (stacked along the ``dev``
axis).

Both substrates accept **batched** label/frontier state (DESIGN.md
section 7): ``relax_spmd`` plans each device's round over the union
frontier of all B queries, the replicated all-reduce simply spans the
``[B, V]`` array, and the mirror substrate ships one ``[B]`` label
vector per dirty boundary vertex (``bytes_synced`` scales by B while
``mirrors_synced`` keeps counting vertices).
``sssp_batch_distributed`` / ``bfs_batch_distributed`` are the
multi-source entry points.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .graph import Graph, INF
from .balancer import (BalancerConfig, RoundStats, RoundStatsDev,
                       relax_spmd, combine_neutral, _note_host_transfer)
from .frontier import multi_source_state
from .operators import Operator
from .partition import PartitionMeta
from . import operators as ops
from . import wire as wirecodec
from .wire import step_logical_bytes


def device_mesh(num_devices: int | None = None):
    """A 1-D ``("dev",)`` mesh over the first ``num_devices`` local
    devices (all of them by default) — what every distributed driver
    here expects."""
    devs = jax.devices()
    if num_devices is not None:
        devs = devs[:num_devices]
    return jax.sharding.Mesh(np.asarray(devs), ("dev",))


def _sync(labels, combine: str):
    if combine == "min":
        return jax.lax.pmin(labels, "dev")
    return jax.lax.psum(labels, "dev")


def make_round_fn(mesh, cfg: BalancerConfig, op: Operator,
                  sync_delta: bool = False, collect_stats: bool = False):
    """Build the jitted one-BSP-round function (replicated sync).

    sync_delta: for ``add``-combine operators the per-device scatter
    accumulates into a zero-initialized delta that is psum'd, then added
    to the replicated base — avoids double counting the base.

    collect_stats: the round function additionally returns a
    ``RoundStatsDev`` whose leaves carry a leading ``dev`` axis — one
    instrumentation record per device per round (Fig 1/5 in SPMD mode).
    ``bytes_synced`` reports the all-reduce's per-device volume —
    ``V * itemsize`` every round, the baseline the mirror substrate
    undercuts; ``bytes_wire`` is what ``cfg.wire``'s codec would put
    on a real wire for the same round (the all-reduce itself stays
    full-width — encoding a commutative reduction tree is the
    transport's job, so the codec is accounting-only here).
    """
    codec = wirecodec.get_codec(cfg.wire, op)

    def round_fn(stacked_g: Graph, values, labels, frontier):
        # shard_map hands each device a [1, ...] block: squeeze to local
        stacked_g = Graph(row_ptr=stacked_g.row_ptr[0],
                          col_idx=stacked_g.col_idx[0],
                          edge_w=stacked_g.edge_w[0])
        # per-device local compute
        if sync_delta:
            delta = jnp.zeros_like(labels)
            out = relax_spmd(stacked_g, values, delta, frontier, cfg, op,
                             collect_stats=collect_stats)
            delta, st = out if collect_stats else (out, None)
            shipped, prev = delta, jnp.zeros_like(delta)
            delta = _sync(delta, "add")
            new = labels + delta
        else:
            out = relax_spmd(stacked_g, values, labels, frontier, cfg, op,
                             collect_stats=collect_stats)
            new, st = out if collect_stats else (out, None)
            shipped, prev = new, labels
            new = _sync(new, op.combine)
        if collect_stats:
            # all-reduce volume spans every label entry: V vertices
            # exchanged (same unit as the mirror substrate's count),
            # each carrying a [B] vector -> bytes scale by the batch
            st = st._replace(
                mirrors_synced=jnp.int32(labels.shape[-1]),
                bytes_synced=jnp.int32(labels.size * labels.dtype.itemsize),
                bytes_wire=codec.allreduce_wire_bytes(shipped, prev))
            # leading axis of size 1 -> stacked to [D, ...] by out_specs
            return new, jax.tree_util.tree_map(lambda x: x[None], st)
        return new

    gspec = Graph(row_ptr=P("dev"), col_idx=P("dev"), edge_w=P("dev"))
    out_specs = P()
    if collect_stats:
        out_specs = (P(), RoundStatsDev(
            *([P("dev")] * len(RoundStatsDev._fields))))
    fn = shard_map(round_fn, mesh=mesh,
                   in_specs=(gspec, P(), P(), P()),
                   out_specs=out_specs,
                   check_rep=False)
    return jax.jit(fn)


def make_fused_traversal_fn(mesh, cfg: BalancerConfig, op: Operator,
                            sync_delta: bool = False,
                            max_rounds: int = 10_000,
                            values_of=lambda l: l,
                            next_frontier=lambda old, new, f: new < old):
    """Build the fused replicated-sync traversal: the whole BSP loop
    as ONE ``lax.while_loop`` *inside* ``shard_map`` (DESIGN.md
    section 11 applied to the distributed runtime).

    The per-round all-reduce keeps labels identical across devices, so
    the derived frontier — and therefore the loop condition — is
    uniform without any extra collective: between dispatch and the
    final label fetch no value crosses to the host.  ``values_of`` /
    ``next_frontier`` move inside the traced loop (the host loop
    applies them between dispatches instead).  Returns
    ``(labels, rounds)`` — both device values."""
    def trav_fn(stacked_g: Graph, labels, frontier):
        g = Graph(row_ptr=stacked_g.row_ptr[0],
                  col_idx=stacked_g.col_idx[0],
                  edge_w=stacked_g.edge_w[0])

        def cond(carry):
            r, lab, fr = carry
            return (r < max_rounds) & jnp.any(fr)

        def body(carry):
            r, lab, fr = carry
            values = values_of(lab)
            if sync_delta:
                delta = jnp.zeros_like(lab)
                delta = relax_spmd(g, values, delta, fr, cfg, op)
                new = lab + _sync(delta, "add")
            else:
                new = _sync(relax_spmd(g, values, lab, fr, cfg, op),
                            op.combine)
            return r + 1, new, next_frontier(lab, new, fr)

        r, labels, _ = jax.lax.while_loop(
            cond, body, (jnp.int32(0), labels, frontier))
        return labels, r

    gspec = Graph(row_ptr=P("dev"), col_idx=P("dev"), edge_w=P("dev"))
    fn = shard_map(trav_fn, mesh=mesh,
                   in_specs=(gspec, P(), P()),
                   out_specs=(P(), P()),
                   check_rep=False)
    return jax.jit(fn)


# ---- master/mirror substrate (DESIGN.md section 6) -------------------------

def make_mirror_round_fn(mesh, cfg: BalancerConfig, op: Operator,
                         meta: PartitionMeta,
                         sync_delta: bool = False,
                         collect_stats: bool = False,
                         values_of=lambda l: l,
                         next_frontier=lambda old, new, f: new < old,
                         post_sync=None, global_of=None,
                         fused: bool = False, max_rounds: int = 10_000,
                         tol: float | None = None):
    """One BSP round over owned state: local ALB round, then Gluon's
    reduce-to-master -> broadcast-to-mirrors pair over the padded mirror
    lists.

    Per-device label/frontier state is carried across rounds as a
    ``[D, B, V]`` array sharded over ``dev`` (``B`` = query batch, 1
    for single-query drivers — the loop canonicalizes).  The invariant
    maintained: after the round, a device's copy is globally correct
    for every vertex it masters or mirrors (= every endpoint of a local
    edge, the only entries the next local round can read or write);
    other entries may be stale, and the final labels are assembled
    owner-by-owner.

    Sync payloads are per-**vertex**: a boundary vertex is dirty when
    any query touched it, and a dirty vertex ships its whole ``[B]``
    label vector plus its int32 index word in one ring step (DESIGN.md
    section 7) — ``mirrors_synced`` counts vertices, ``bytes_synced``
    is the logical volume (index side included), and ``bytes_wire``
    the post-encode volume under ``cfg.wire``'s codec
    (repro.core.wire), which both rings route every payload through.

    ``values_of`` / ``next_frontier`` / ``post_sync`` are traced inside
    ``shard_map`` so frontier and value derivation stay device-local —
    only a scalar activity count (and a residual, for convergence-driven
    drivers) crosses to the host each round.

    ``global_of`` (optional): ``(labels, owned_mask) -> scalar``
    evaluated on each device over its owned master range — the one
    slice of pre-round state guaranteed globally correct — and
    ``psum``'d across devices; the global scalar is then passed as a
    third argument to ``post_sync(labels, acc, glob)``.  PageRank uses
    it for the dangling-mass sum (no extra host traffic: the reduction
    rides the round's existing collectives).

    ``fused=True`` wraps the same round body in a ``lax.while_loop``
    *inside* ``shard_map`` (DESIGN.md section 11): the activity count
    and residual that the host loop fetches every round become carried
    loop state — the psum/pmax in the round body make them uniform
    across devices, so the loop condition is collective-safe — and the
    traversal returns ``(labels_dev, frontier_dev, rounds)`` after a
    single dispatch.  Stats collection stays per-dispatch, so
    ``fused`` requires ``collect_stats=False``.
    """
    ndev = meta.num_devices
    v = meta.num_vertices
    codec = wirecodec.get_codec(cfg.wire, op)
    if fused and collect_stats:
        raise ValueError("fused mirror traversal does not collect "
                         "per-round stats (one dispatch, no per-round "
                         "host boundary)")
    if post_sync is None:
        post_sync = ((lambda lab, acc: lab + acc) if sync_delta
                     else (lambda lab, acc: acc))

    def round_fn(stacked_g: Graph, mirror_t, incoming_t, lo_t, hi_t,
                 labels0, frontier0):
        g = Graph(row_ptr=stacked_g.row_ptr[0],
                  col_idx=stacked_g.col_idx[0],
                  edge_w=stacked_g.edge_w[0])
        mirror_t = mirror_t[0]        # [D, L]: rows indexed by owner
        incoming_t = incoming_t[0]    # [D, L]: rows indexed by toucher
        lo, hi = lo_t[0], hi_t[0]     # my owned range
        labels0, frontier0 = labels0[0], frontier0[0]  # [B, V]
        b = labels0.shape[0]
        me = jax.lax.axis_index("dev")

        def one_round(labels, frontier):
            values = values_of(labels)
            base = jnp.zeros_like(labels) if sync_delta else labels
            out = relax_spmd(g, values, base, frontier, cfg, op,
                             collect_stats=collect_stats,
                             return_dirty=True)
            if collect_stats:
                new, st, dirty = out
            else:
                (new, dirty), st = out, None
            dirty_v = jnp.any(dirty, axis=0)           # [V] any-query
            # non-dirty mirror slots carry the combiner's identity so
            # skipping them is exact (same rule as the balancer's
            # scatter)
            neutral = combine_neutral(op.combine, new.dtype)

            perm_fwd = [[(i, (i + s) % ndev) for i in range(ndev)]
                        for s in range(ndev)]
            perm_bwd = [[(i, (i - s) % ndev) for i in range(ndev)]
                        for s in range(ndev)]

            # ---- reduce-to-master: each ring step s ships my dirty
            # values for vertices mastered s hops ahead; the sentinel-V
            # padding is dropped by the scatter, non-dirty slots carry
            # the neutral.  The payload crosses the ring through
            # ``cfg.wire``'s codec: the reduce direction's delta
            # reference is the round-entry labels (zeros in delta-sync
            # mode, where the payload already IS a delta) — both ends
            # hold identical copies for every real mirror-list slot
            # because the previous broadcast overwrote them.
            prev_reduce = (jnp.zeros_like(labels) if sync_delta
                           else labels)
            acc = new
            n_exch = jnp.int32(0)
            b_log = jnp.int32(0)
            b_wire = jnp.int32(0)
            for s in range(1, ndev):
                out_idx = mirror_t[(me + s) % ndev]
                safe = jnp.where(out_idx < v, out_idx, 0)
                live = (out_idx < v) & dirty_v[safe]
                payload = jnp.where(live[None], new[:, safe], neutral)
                if collect_stats:
                    n_exch += jnp.sum(live.astype(jnp.int32))
                    b_log += step_logical_bytes(
                        live, b, new.dtype.itemsize)
                    b_wire += codec.step_wire_bytes(
                        payload, prev_reduce[:, safe], live, op)
                recv = jax.lax.ppermute(
                    codec.encode(payload, prev_reduce[:, safe], op),
                    "dev", perm_fwd[s])
                in_idx = incoming_t[(me - s) % ndev]
                safe_in = jnp.where(in_idx < v, in_idx, 0)
                recv = codec.decode(recv, prev_reduce[:, safe_in], op,
                                    new.dtype)
                if op.combine == "min":
                    acc = acc.at[:, in_idx].min(recv, mode="drop")
                else:
                    acc = acc.at[:, in_idx].add(recv, mode="drop")

            if global_of is not None:
                ovids = jnp.arange(v, dtype=jnp.int32)
                omask = (ovids >= lo) & (ovids < hi)
                glob = jax.lax.psum(global_of(labels, omask), "dev")
                final = post_sync(labels, acc, glob)
            else:
                final = post_sync(labels, acc)

            # ---- broadcast-to-mirrors: masters push the reduced
            # values back along the reverse ring; mirrors overwrite
            # their copies.  Here the delta reference is always the
            # round-entry labels: the broadcast ships actual labels
            # even in delta-sync mode, and every mirror-list slot's
            # round-entry copy agrees across devices (the previous
            # broadcast's unconditional overwrite).
            gdirty = jnp.any(final != labels, axis=0)  # [V]
            for s in range(1, ndev):
                out_idx = incoming_t[(me - s) % ndev]
                safe = jnp.where(out_idx < v, out_idx, 0)
                live = (out_idx < v) & gdirty[safe]
                payload = final[:, safe]
                if collect_stats:
                    n_exch += jnp.sum(live.astype(jnp.int32))
                    b_log += step_logical_bytes(
                        live, b, final.dtype.itemsize)
                    b_wire += codec.step_wire_bytes(
                        payload, labels[:, safe], live, op)
                recv = jax.lax.ppermute(
                    codec.encode(payload, labels[:, safe], op),
                    "dev", perm_bwd[s])
                in_idx = mirror_t[(me + s) % ndev]
                safe_in = jnp.where(in_idx < v, in_idx, 0)
                # signed=False: the broadcast ships full labels, which
                # are non-negative — unsigned narrow words zero-extend
                # (kcore degrees in [2^15, 2^16) stay exact)
                recv = codec.decode(recv, labels[:, safe_in], op,
                                    final.dtype, signed=False)
                final = final.at[:, in_idx].set(recv, mode="drop")

            new_frontier = next_frontier(labels, final, frontier)
            active = jax.lax.psum(
                jnp.sum(new_frontier.astype(jnp.int32)), "dev")
            vids = jnp.arange(v, dtype=jnp.int32)
            owned = (vids >= lo) & (vids < hi)
            resid = jax.lax.pmax(jnp.max(jnp.where(
                owned[None],
                jnp.abs(final.astype(jnp.float32)
                        - labels.astype(jnp.float32)),
                0.0)), "dev")
            if collect_stats:
                # bytes_synced is the LOGICAL exchange volume: every
                # live vertex ships its int32 index word alongside the
                # [B] payload (the index side used to be dropped from
                # the count — see tests/test_mirror_sync.py's
                # accounting regression); bytes_wire is the post-encode
                # volume under cfg.wire's codec.
                st = st._replace(
                    mirrors_synced=n_exch,
                    bytes_synced=b_log,
                    bytes_wire=b_wire)
            return final, new_frontier, active, resid, st

        if not fused:
            final, new_frontier, active, resid, st = one_round(
                labels0, frontier0)
            outs = (final[None], new_frontier[None], active, resid)
            if collect_stats:
                outs += (jax.tree_util.tree_map(lambda x: x[None], st),)
            return outs

        # fused: the host loop's per-round observations (activity,
        # residual) become carried state; both are psum/pmax-reduced in
        # the body, so the condition is uniform across devices.
        def cond(carry):
            r, lab, fr, active, resid = carry
            ok = (r < max_rounds) & (active > 0)
            if tol is not None:
                ok = ok & (resid >= tol)
            return ok

        def body(carry):
            r, lab, fr, active, resid = carry
            final, nfr, active, resid, _ = one_round(lab, fr)
            return r + 1, final, nfr, active, resid

        active0 = jax.lax.psum(
            jnp.sum(frontier0.astype(jnp.int32)), "dev")
        r, final, fr, _, _ = jax.lax.while_loop(
            cond, body, (jnp.int32(0), labels0, frontier0, active0,
                         jnp.float32(jnp.inf)))
        return final[None], fr[None], r

    gspec = Graph(row_ptr=P("dev"), col_idx=P("dev"), edge_w=P("dev"))
    if fused:
        out_specs = (P("dev"), P("dev"), P())
    else:
        out_specs = (P("dev"), P("dev"), P(), P())
        if collect_stats:
            out_specs += (RoundStatsDev(
                *([P("dev")] * len(RoundStatsDev._fields))),)
    fn = shard_map(round_fn, mesh=mesh,
                   in_specs=(gspec, P("dev"), P("dev"), P("dev"), P("dev"),
                             P("dev"), P("dev")),
                   out_specs=out_specs,
                   check_rep=False)
    return jax.jit(fn)


def _mirror_tables(meta: PartitionMeta):
    """Device-resident sync metadata: the padded mirror lists in both
    orientations plus the owned ranges."""
    mirror_t = jnp.asarray(meta.mirror_idx)                       # [D,D,L]
    incoming_t = jnp.asarray(meta.mirror_idx.transpose(1, 0, 2))  # [o,d,L]
    lo = jnp.asarray(meta.master_bounds[:-1], jnp.int32)
    hi = jnp.asarray(meta.master_bounds[1:], jnp.int32)
    return mirror_t, incoming_t, lo, hi


def assemble_owned(labels_dev, meta: PartitionMeta):
    """Gather each vertex's label from its master's copy — the only
    copies guaranteed globally correct under the mirror substrate.
    Accepts ``[D, V]`` or batched ``[D, B, V]`` state (returns
    ``[V]`` / ``[B, V]``)."""
    arr = np.asarray(labels_dev)
    vsel = np.arange(meta.num_vertices)
    if arr.ndim == 3:
        # advanced indices around the batch slice land in front: [V, B]
        return jnp.asarray(arr[meta.owner, :, vsel].T)
    return jnp.asarray(arr[meta.owner, vsel])


def stats_per_device(st: RoundStatsDev) -> list[RoundStats]:
    """Split a dev-stacked RoundStatsDev into one host RoundStats per
    device."""
    ndev = st.frontier_size.shape[0]
    return [RoundStats.from_device(
        jax.tree_util.tree_map(lambda x: x[d], st)) for d in range(ndev)]


def _any_host(frontier) -> bool:
    """The replicated host loop's per-round frontier probe — a
    blocking device->host sync, counted against the traversal's
    ``host_transfers`` (the quantity ``mode='fused'`` drives to
    zero)."""
    _note_host_transfer()
    return bool(jnp.any(frontier))


def _require_push_direction(cfg: BalancerConfig) -> None:
    """The distributed runtime is push-only (partitions are cut along
    out-edges; the sync substrates ship scatter targets) — refuse
    direction-optimized configs instead of silently running push."""
    if cfg.direction != "push":
        raise ValueError(
            f"the distributed runtime is push-only; "
            f"cfg.direction={cfg.direction!r} is not supported "
            f"(DESIGN.md section 9)")


def _require_meta(meta, sync):
    if sync not in ("replicated", "mirror"):
        raise ValueError(f"unknown sync {sync!r} (replicated|mirror)")
    if sync == "mirror" and meta is None:
        raise ValueError("sync='mirror' needs the PartitionMeta returned "
                         "by partition()")


def run_distributed(stacked_g: Graph, mesh, op: Operator,
                    init_labels, init_frontier,
                    cfg: BalancerConfig = BalancerConfig(),
                    values_of=lambda l: l,
                    next_frontier=lambda old, new, f: new < old,
                    sync_delta: bool = False,
                    max_rounds: int = 10_000,
                    collect_stats: bool = False,
                    sync: str = "replicated",
                    meta: PartitionMeta | None = None,
                    mode: str = "host"):
    """Generic distributed data-driven loop. Returns (labels, rounds,
    total_seconds) — or, with ``collect_stats=True``, (labels, rounds,
    total_seconds, stats) where ``stats[round][device]`` is a host
    :class:`RoundStats` — the compute/comm split feeds the Fig 7/11
    breakdown and the per-device load plots.

    ``sync="mirror"`` (requires ``meta``) swaps the whole-array
    all-reduce for the dirty-tracked boundary exchange; labels and
    frontier stay per-device inside the loop and only a scalar activity
    count comes back to the host each round.

    ``mode="fused"`` dispatches the whole traversal as ONE
    ``lax.while_loop`` inside ``shard_map`` (DESIGN.md section 11):
    zero host syncs between rounds for either substrate.  Per-round
    stats need the per-round host boundary, so fused requires
    ``collect_stats=False``.

    The distributed runtime is push-only: partitions are cut along
    out-edges and the sync substrates exchange scatter targets, so
    direction-optimized configs (DESIGN.md section 9) are refused
    rather than silently run as push.
    """
    _require_push_direction(cfg)
    _require_meta(meta, sync)
    # config-time codec/operator pairing check: a quantize wire on an
    # operator that declares no safe narrowing must fail HERE, before
    # any round is traced or run
    wirecodec.get_codec(cfg.wire, op, init_labels.dtype)
    if mode not in ("host", "fused"):
        raise ValueError(f"unknown distributed mode {mode!r} "
                         "(host|fused)")
    if mode == "fused" and collect_stats:
        raise ValueError("mode='fused' runs with collect_stats=False "
                         "(per-round stats need the per-round host "
                         "boundary)")
    if sync == "mirror":
        return _run_mirror(stacked_g, mesh, op, init_labels, init_frontier,
                           cfg, values_of, next_frontier, sync_delta,
                           max_rounds, collect_stats, meta, mode=mode)
    if mode == "fused":
        trav_fn = make_fused_traversal_fn(
            mesh, cfg, op, sync_delta=sync_delta, max_rounds=max_rounds,
            values_of=values_of, next_frontier=next_frontier)
        t0 = time.perf_counter()
        labels, r = trav_fn(stacked_g, init_labels, init_frontier)
        jax.block_until_ready(labels)
        return labels, int(r), time.perf_counter() - t0
    round_fn = make_round_fn(mesh, cfg, op, sync_delta=sync_delta,
                             collect_stats=collect_stats)
    labels, frontier = init_labels, init_frontier
    rounds = 0
    stats = [] if collect_stats else None
    t0 = time.perf_counter()
    while rounds < max_rounds and _any_host(frontier):
        old = labels
        out = round_fn(stacked_g, values_of(labels), labels, frontier)
        if collect_stats:
            labels, st = out
            stats.append(stats_per_device(st))
        else:
            labels = out
        jax.block_until_ready(labels)
        frontier = next_frontier(old, labels, frontier)
        rounds += 1
    total = time.perf_counter() - t0
    if collect_stats:
        return labels, rounds, total, stats
    return labels, rounds, total


def _run_mirror(stacked_g, mesh, op, init_labels, init_frontier, cfg,
                values_of, next_frontier, sync_delta, max_rounds,
                collect_stats, meta: PartitionMeta, post_sync=None,
                tol: float | None = None, global_of=None,
                mode: str = "host"):
    """Owned-state loop shared by the data-driven drivers and the
    convergence-driven ones: stops when the frontier empties, the round
    budget runs out, or (``tol`` set) the owned-entry residual drops
    below it.  State is carried batched (``[D, B, V]``); un-batched
    callers get the query axis added here and squeezed on return.
    ``mode="fused"`` runs the whole loop on device in one dispatch
    (see :func:`make_mirror_round_fn`)."""
    batched = init_labels.ndim == 2
    if not batched:
        init_labels = init_labels[None]
        init_frontier = init_frontier[None]
    mirror_t, incoming_t, lo, hi = _mirror_tables(meta)
    ndev = meta.num_devices
    labels_dev = jnp.tile(init_labels[None], (ndev, 1, 1))
    frontier_dev = jnp.tile(init_frontier[None], (ndev, 1, 1))
    if mode == "fused":
        trav_fn = make_mirror_round_fn(
            mesh, cfg, op, meta, sync_delta=sync_delta,
            collect_stats=False, values_of=values_of,
            next_frontier=next_frontier, post_sync=post_sync,
            global_of=global_of, fused=True, max_rounds=max_rounds,
            tol=tol)
        t0 = time.perf_counter()
        labels_dev, frontier_dev, r = trav_fn(
            stacked_g, mirror_t, incoming_t, lo, hi,
            labels_dev, frontier_dev)
        jax.block_until_ready(labels_dev)
        labels = assemble_owned(labels_dev, meta)
        if not batched:
            labels = labels[0]
        return labels, int(r), time.perf_counter() - t0
    round_fn = make_mirror_round_fn(
        mesh, cfg, op, meta, sync_delta=sync_delta,
        collect_stats=collect_stats, values_of=values_of,
        next_frontier=next_frontier, post_sync=post_sync,
        global_of=global_of)
    # the per-round activity probe below is a noted transfer site;
    # this one is the pre-loop seed count, paid once per traversal
    active = int(jnp.sum(init_frontier))  # repro: allow[host-sync] -- one-time pre-loop seed count
    rounds = 0
    stats = [] if collect_stats else None
    t0 = time.perf_counter()
    while rounds < max_rounds and active > 0:
        out = round_fn(stacked_g, mirror_t, incoming_t, lo, hi,
                       labels_dev, frontier_dev)
        if collect_stats:
            labels_dev, frontier_dev, active_a, resid, st = out
            stats.append(stats_per_device(st))
        else:
            labels_dev, frontier_dev, active_a, resid = out
        active = int(active_a)
        _note_host_transfer()      # the activity/residual probe blocks
        rounds += 1
        if tol is not None and float(resid) < tol:
            break
    labels = assemble_owned(labels_dev, meta)
    if not batched:
        labels = labels[0]
    total = time.perf_counter() - t0
    if collect_stats:
        return labels, rounds, total, stats
    return labels, rounds, total


# ---- distributed application drivers --------------------------------------

def sssp_distributed(stacked_g: Graph, mesh, source: int,
                     cfg: BalancerConfig = BalancerConfig(),
                     max_rounds: int = 10_000,
                     collect_stats: bool = False,
                     sync: str = "replicated",
                     meta: PartitionMeta | None = None,
                     mode: str = "host"):
    """Distributed single-source SSSP over a partitioned (stacked-CSR)
    graph; ``sync`` selects the replicated all-reduce or the
    master/mirror boundary exchange (DESIGN.md section 6);
    ``mode="fused"`` runs the whole traversal in one device dispatch
    (DESIGN.md section 11)."""
    v = stacked_g.row_ptr.shape[-1] - 1
    dist = jnp.full((v,), INF, jnp.int32).at[source].set(0)
    frontier = jnp.zeros((v,), bool).at[source].set(True)
    return run_distributed(stacked_g, mesh, ops.SSSP_RELAX, dist, frontier,
                           cfg, max_rounds=max_rounds,
                           collect_stats=collect_stats, sync=sync,
                           meta=meta, mode=mode)


def bfs_distributed(stacked_g: Graph, mesh, source: int,
                    cfg: BalancerConfig = BalancerConfig(),
                    max_rounds: int = 10_000,
                    collect_stats: bool = False,
                    sync: str = "replicated",
                    meta: PartitionMeta | None = None,
                    mode: str = "host"):
    """Distributed single-source BFS (see :func:`sssp_distributed`)."""
    v = stacked_g.row_ptr.shape[-1] - 1
    lvl = jnp.full((v,), INF, jnp.int32).at[source].set(0)
    frontier = jnp.zeros((v,), bool).at[source].set(True)
    return run_distributed(stacked_g, mesh, ops.BFS_HOP, lvl, frontier,
                           cfg, max_rounds=max_rounds,
                           collect_stats=collect_stats, sync=sync,
                           meta=meta, mode=mode)


def sssp_batch_distributed(stacked_g: Graph, mesh, sources,
                           cfg: BalancerConfig = BalancerConfig(),
                           max_rounds: int = 10_000,
                           collect_stats: bool = False,
                           sync: str = "replicated",
                           meta: PartitionMeta | None = None,
                           mode: str = "host"):
    """Batched multi-source SSSP on the distributed runtime: B queries
    share every BSP round (union-frontier rounds per device) and, under
    ``sync="mirror"``, every boundary exchange (one ``[B]`` vector per
    dirty vertex — DESIGN.md section 7).  Returns ``labels[B, V]``."""
    v = stacked_g.row_ptr.shape[-1] - 1
    dist, frontier = multi_source_state(v, sources, INF)
    return run_distributed(stacked_g, mesh, ops.SSSP_RELAX, dist, frontier,
                           cfg, max_rounds=max_rounds,
                           collect_stats=collect_stats, sync=sync,
                           meta=meta, mode=mode)


def bfs_batch_distributed(stacked_g: Graph, mesh, sources,
                          cfg: BalancerConfig = BalancerConfig(),
                          max_rounds: int = 10_000,
                          collect_stats: bool = False,
                          sync: str = "replicated",
                          meta: PartitionMeta | None = None,
                          mode: str = "host"):
    """Batched multi-source BFS (see :func:`sssp_batch_distributed`)."""
    v = stacked_g.row_ptr.shape[-1] - 1
    lvl, frontier = multi_source_state(v, sources, INF)
    return run_distributed(stacked_g, mesh, ops.BFS_HOP, lvl, frontier,
                           cfg, max_rounds=max_rounds,
                           collect_stats=collect_stats, sync=sync,
                           meta=meta, mode=mode)


def cc_distributed(stacked_g: Graph, mesh,
                   cfg: BalancerConfig = BalancerConfig(),
                   max_rounds: int = 10_000,
                   collect_stats: bool = False,
                   sync: str = "replicated",
                   meta: PartitionMeta | None = None,
                   mode: str = "host"):
    """Distributed connected components by min-label propagation
    (expects a symmetrized input; see :func:`sssp_distributed` for the
    ``sync`` substrates)."""
    v = stacked_g.row_ptr.shape[-1] - 1
    comp = jnp.arange(v, dtype=jnp.int32)
    frontier = jnp.ones((v,), bool)
    return run_distributed(stacked_g, mesh, ops.CC_MIN, comp, frontier,
                           cfg, max_rounds=max_rounds,
                           collect_stats=collect_stats, sync=sync,
                           meta=meta, mode=mode)


def kcore_distributed(stacked_g: Graph, mesh, k: int,
                      cfg: BalancerConfig = BalancerConfig(),
                      max_rounds: int = 10_000,
                      collect_stats: bool = False,
                      sync: str = "replicated",
                      meta: PartitionMeta | None = None,
                      mode: str = "host"):
    """Distributed k-core over a partitioned *symmetrized* graph.

    Degrees only decrease, so "dead" (< k) is monotone and the
    data-driven loop is exactly :func:`run_distributed` with the
    newly-crossed-the-threshold frontier rule; each dead vertex pushes
    its -1 decrements once, through the delta sync (add combiner).
    Returns in_core labels (1 = in the k-core), like the single-device
    driver.
    """
    rp = stacked_g.row_ptr
    deg = jnp.sum(rp[:, 1:] - rp[:, :-1], axis=0).astype(jnp.int32)
    frontier = (deg < k) & (deg > 0)
    out = run_distributed(
        stacked_g, mesh, ops.KCORE_DEC, deg, frontier, cfg,
        next_frontier=lambda old, new, f: (new < k) & (old >= k),
        sync_delta=True, max_rounds=max_rounds,
        collect_stats=collect_stats, sync=sync, meta=meta, mode=mode)
    labels, rest = out[0], out[1:]
    in_core = (labels >= k).astype(jnp.int32)
    return (in_core,) + rest


@partial(jax.jit, static_argnames=("damping",))
def _pr_update(rank, inv_out, sink, acc, damping: float):
    """Replicated PageRank's post-round rank update + residual as one
    shared jitted subgraph: the host loop calls it between dispatches,
    the fused while_loop inlines it — same fusion decisions both ways,
    so the f32 rounding (FMA contraction of the damping update) is
    bitwise-identical across modes."""
    v = rank.shape[0]
    dangling = jnp.sum(jnp.where(sink, rank, 0.0))
    new_rank = (1.0 - damping) / v + damping * (acc + dangling / v)
    delta = jnp.max(jnp.abs(new_rank - rank))
    return new_rank, delta


def pagerank_distributed(stacked_rg: Graph, mesh, out_degrees,
                         damping: float = 0.85, tol: float = 1e-6,
                         cfg: BalancerConfig = BalancerConfig(),
                         max_rounds: int = 1000,
                         collect_stats: bool = False,
                         sync: str = "replicated",
                         meta: PartitionMeta | None = None,
                         mode: str = "host"):
    """stacked_rg: partitioned *reverse* graph (pull traverses
    in-edges).  Dangling vertices (out-degree 0) redistribute their
    rank mass uniformly each round, matching the single-device
    :func:`repro.core.apps.drivers.pagerank` exactly (under the mirror
    substrate the dangling sum is reduced over owned master ranges via
    the ``global_of`` hook — exact and free of extra host traffic).
    ``mode="fused"`` moves the whole power iteration — including the
    residual check that otherwise blocks the host every round — into
    one ``lax.while_loop`` inside ``shard_map``."""
    _require_push_direction(cfg)
    _require_meta(meta, sync)
    # config-time codec/operator pairing check (quantize forbids float
    # rank payloads, and PR_PULL declares no narrowing anyway)
    wirecodec.get_codec(cfg.wire, ops.PR_PULL, jnp.float32)
    if mode not in ("host", "fused"):
        raise ValueError(f"unknown distributed mode {mode!r} "
                         "(host|fused)")
    if mode == "fused" and collect_stats:
        raise ValueError("mode='fused' runs with collect_stats=False "
                         "(per-round stats need the per-round host "
                         "boundary)")
    v = stacked_rg.row_ptr.shape[-1] - 1
    outdeg = out_degrees.astype(jnp.float32)
    inv_out = jnp.where(outdeg > 0, 1.0 / jnp.maximum(outdeg, 1.0), 0.0)
    sink = outdeg == 0
    rank = jnp.full((v,), 1.0 / v, jnp.float32)
    frontier = jnp.ones((v,), bool)
    if sync == "mirror":
        # topology-driven: full frontier every round, per-round rank
        # update as post_sync, convergence via the owned-entry residual
        return _run_mirror(
            stacked_rg, mesh, ops.PR_PULL, rank, frontier, cfg,
            values_of=lambda r: r * inv_out,
            next_frontier=lambda old, new, f: f,
            sync_delta=True, max_rounds=max_rounds,
            collect_stats=collect_stats, meta=meta,
            post_sync=lambda lab, acc, dang: (
                (1.0 - damping) / v + damping * (acc + dang / v)),
            global_of=lambda lab, owned: jnp.sum(
                jnp.where(owned[None] & sink[None], lab, 0.0)),
            tol=tol, mode=mode)
    if mode == "fused":
        def trav_fn(sg: Graph, rank, inv_out, sink):
            g = Graph(row_ptr=sg.row_ptr[0], col_idx=sg.col_idx[0],
                      edge_w=sg.edge_w[0])
            fr = jnp.ones((v,), bool)

            def cond(carry):
                r, rank, delta = carry
                return (r < max_rounds) & (delta >= tol)

            def body(carry):
                r, rank, delta = carry
                contrib = rank * inv_out
                acc = relax_spmd(g, contrib,
                                 jnp.zeros((v,), jnp.float32), fr,
                                 cfg, ops.PR_PULL)
                acc = _sync(acc, "add")
                new_rank, delta = _pr_update(rank, inv_out, sink, acc,
                                             float(damping))
                return r + 1, new_rank, delta

            r, rank, _ = jax.lax.while_loop(
                cond, body,
                (jnp.int32(0), rank, jnp.float32(jnp.inf)))
            return rank, r

        gspec = Graph(row_ptr=P("dev"), col_idx=P("dev"),
                      edge_w=P("dev"))
        fn = jax.jit(shard_map(trav_fn, mesh=mesh,
                               in_specs=(gspec, P(), P(), P()),
                               out_specs=(P(), P()),
                               check_rep=False))
        t0 = time.perf_counter()
        rank, r = fn(stacked_rg, rank, inv_out, sink)
        jax.block_until_ready(rank)
        return rank, int(r), time.perf_counter() - t0
    round_fn = make_round_fn(mesh, cfg, ops.PR_PULL, sync_delta=True,
                             collect_stats=collect_stats)
    rounds = 0
    stats = [] if collect_stats else None
    t0 = time.perf_counter()
    while rounds < max_rounds:
        contrib = rank * inv_out
        out = round_fn(stacked_rg, contrib, jnp.zeros((v,), jnp.float32),
                       frontier)
        if collect_stats:
            acc, st = out
            stats.append(stats_per_device(st))
        else:
            acc = out
        new_rank, delta_dev = _pr_update(rank, inv_out, sink, acc,
                                         float(damping))
        delta = float(delta_dev)
        _note_host_transfer()      # the residual check blocks
        rank = new_rank
        rounds += 1
        if delta < tol:
            break
    total = time.perf_counter() - t0
    if collect_stats:
        return rank, rounds, total, stats
    return rank, rounds, total
