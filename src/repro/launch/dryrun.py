import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (16×16 single-pod / 2×16×16 multi-pod) and extracts the
artifacts the roofline analysis reads:

* ``compiled.memory_analysis()``  — bytes per device (fits/doesn't),
* ``compiled.cost_analysis()``    — FLOPs + HBM bytes (per device,
  post-SPMD-partitioning),
* collective bytes parsed from the partitioned HLO text (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all            # subprocess per cell
"""
import argparse
import json
import re
import subprocess
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, get_config, shape_by_name,
                           applicable_shapes)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch import sharding as SH
from repro.models import transformer as T
from repro.optim import OptConfig, adamw_init
from repro.train.steps import make_train_step, make_prefill_step, \
    make_decode_step

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[dims]' group."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of each collective op kind (per device,
    since the module is the SPMD-partitioned one)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        for kind in _COLLECTIVES:
            # match '= <shape> kind(' — the op result type precedes name
            if f" {kind}(" in ls or f" {kind}-start(" in ls:
                lhs = ls.split("=", 1)
                if len(lhs) != 2:
                    continue
                rhs = lhs[1]
                shape_part = rhs.split(kind)[0]
                out[kind] += _shape_bytes(shape_part)
                counts[kind] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


VARIANT_FLAGS = ("expert_fsdp", "master_bf16", "seqpar", "logits_bf16",
                 "moe_data", "moe_group")


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  cfg_override=None, unroll: bool = False,
                  opts: frozenset = frozenset()):
    for o in opts:
        assert o in VARIANT_FLAGS, o
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = shape_by_name(shape_name)
    if "moe_group" in opts and cfg.moe is not None:
        import dataclasses
        groups = 32 if multi_pod else 16      # = data axes size
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=groups))
    mesh = make_production_mesh(multi_pod=multi_pod)
    shard_fn = SH.make_shard_fn(mesh, multi_pod,
                                seqpar="seqpar" in opts,
                                moe_data="moe_data" in opts)
    specs = input_specs(cfg, shape)
    T.set_logits_dtype(jnp.bfloat16 if "logits_bf16" in opts
                       else jnp.float32)

    params_shape = jax.eval_shape(partial(T.init, cfg=cfg),
                                  jax.random.PRNGKey(0))
    if "master_bf16" in opts:
        params_shape = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(
                sd.shape, jnp.bfloat16 if len(sd.shape) > 1
                else sd.dtype), params_shape)
    pspec = SH.param_specs(params_shape,
                           expert_fsdp="expert_fsdp" in opts)
    psh = named(mesh, pspec)
    dp = SH.dp_axes_for(multi_pod, shape.global_batch)

    with mesh:
        if shape.kind == "train":
            master = "master_bf16" in opts
            opt_shape = jax.eval_shape(
                partial(adamw_init, master_weights=master), params_shape)
            osh = named(mesh, SH.opt_specs(pspec, master_weights=master))
            bsh = named(mesh, SH.batch_specs(
                multi_pod, cfg.num_codebooks,
                with_prefix=cfg.prefix_len > 0,
                global_batch=shape.global_batch))
            step = make_train_step(cfg, OptConfig(master_weights=master),
                                   shard_fn, unroll=unroll)
            lowered = jax.jit(
                step,
                in_shardings=(psh, osh, bsh),
                out_shardings=(psh, osh,
                               named(mesh, {"loss": P(), "ce": P(),
                                            "grad_norm": P()})),
            ).lower(params_shape, opt_shape, specs)
        elif shape.kind == "prefill":
            csh = named(mesh, SH.cache_specs(
                cfg, multi_pod, shape.global_batch, shape.seq_len))
            tok_sh = NamedSharding(
                mesh, P(dp, *([None] * (1 if cfg.num_codebooks == 1
                                        else 2))))
            logits_spec = (P(dp, None, None) if cfg.num_codebooks == 1
                           else P(dp, None, None, None))
            step = make_prefill_step(cfg, shard_fn, unroll=unroll)
            lowered = jax.jit(
                step,
                in_shardings=(psh, tok_sh, csh),
                out_shardings=(NamedSharding(mesh, logits_spec), csh),
            ).lower(params_shape, specs["tokens"], specs["cache"])
        else:  # decode
            csh = named(mesh, SH.cache_specs(
                cfg, multi_pod, shape.global_batch, shape.seq_len))
            tok_sh = NamedSharding(
                mesh, P(dp, *([None] * (1 if cfg.num_codebooks == 1
                                        else 2))))
            logits_spec = (P(dp, None, None) if cfg.num_codebooks == 1
                           else P(dp, None, None, None))
            step = make_decode_step(cfg, shard_fn, unroll=unroll)
            lowered = jax.jit(
                step,
                in_shardings=(psh, tok_sh, csh),
                out_shardings=(NamedSharding(mesh, logits_spec), csh),
            ).lower(params_shape, specs["token"], specs["cache"])
    return lowered, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, save_hlo: bool = False,
             opts: frozenset = frozenset()) -> dict:
    t0 = time.time()
    lowered, mesh = build_lowered(arch, shape_name, multi_pod,
                                  opts=opts)
    t_lower = time.time() - t0
    with mesh:
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = dict(compiled.memory_analysis().__dict__) \
        if hasattr(compiled.memory_analysis(), "__dict__") else {}
    ma = compiled.memory_analysis()
    mem = {k: int(getattr(ma, k)) for k in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
           if hasattr(ma, k)}
    cost = compiled.cost_analysis()
    cost = {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float))} if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "opts": sorted(opts),
        "devices": 512 if multi_pod else 256,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collectives": coll,
        "ok": True,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = ("__" + "-".join(sorted(opts))) if opts else ""
        tag = f"{arch}__{shape_name}__{result['mesh']}{suffix}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
        if save_hlo:
            with open(os.path.join(out_dir, tag + ".hlo"), "w") as f:
                f.write(hlo)
    return result


def _cost_point(arch, shape_name, multi_pod, num_layers,
                opts: frozenset = frozenset()):
    """Lower an UNROLLED reduced-depth twin and return (flops, bytes,
    collective_bytes) per device — one point of the linear-in-L model."""
    import dataclasses
    from repro.models import layers as LY
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, num_layers=num_layers)
    LY.set_attn_impl("plain")       # no scan: trip counts fully visible
    try:
        lowered, mesh = build_lowered(arch, shape_name, multi_pod,
                                      cfg_override=cfg, unroll=True,
                                      opts=opts)
        with mesh:
            compiled = lowered.compile()
    finally:
        LY.set_attn_impl("chunked")
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]))


def cost_extract(arch: str, shape_name: str, multi_pod: bool,
                 out_dir: str | None = None,
                 opts: frozenset = frozenset()) -> dict:
    """Two-point linear extrapolation of per-device FLOPs / HBM bytes /
    collective bytes to the full layer count (scan bodies are counted
    once by HloCostAnalysis, so the extraction lowers scan-free
    unrolled twins at small L)."""
    cfg = get_config(arch)
    if cfg.family == "hybrid":
        l1, l2 = cfg.attn_every, 2 * cfg.attn_every
    else:
        l1, l2 = 1, 2
    f1, b1, c1 = _cost_point(arch, shape_name, multi_pod, l1, opts)
    f2, b2, c2 = _cost_point(arch, shape_name, multi_pod, l2, opts)
    n = cfg.num_layers
    per_layer = ((f2 - f1) / (l2 - l1), (b2 - b1) / (l2 - l1),
                 (c2 - c1) / (l2 - l1))
    base = (f1 - per_layer[0] * l1, b1 - per_layer[1] * l1,
            c1 - per_layer[2] * l1)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "opts": sorted(opts),
        "flops_per_device": base[0] + per_layer[0] * n,
        "hbm_bytes_per_device": base[1] + per_layer[1] * n,
        "collective_bytes_per_device": base[2] + per_layer[2] * n,
        "points": {"l": [l1, l2], "flops": [f1, f2],
                   "bytes": [b1, b2], "coll": [c1, c2]},
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = ("__" + "-".join(sorted(opts))) if opts else ""
        tag = f"{arch}__{shape_name}__{result['mesh']}{suffix}__cost"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--cost-extract", action="store_true",
                    help="extrapolated roofline terms instead of the "
                         "full-depth compile")
    ap.add_argument("--opts", default="",
                    help="comma-separated variant flags: "
                         + ",".join(VARIANT_FLAGS))
    args = ap.parse_args()
    opts = frozenset(o for o in args.opts.split(",") if o)

    if args.all:
        failures = []
        for arch, shape in all_cells():
            for mp in ([False, True] if args.both_meshes
                       else [args.multi_pod]):
                tag = f"{arch} {shape} {'2x16x16' if mp else '16x16'}"
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", args.out]
                if mp:
                    cmd.append("--multi-pod")
                if args.save_hlo:
                    cmd.append("--save-hlo")
                if args.cost_extract:
                    cmd.append("--cost-extract")
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                ok = r.returncode == 0
                print(f"[{'OK' if ok else 'FAIL'}] {tag} "
                      f"({time.time() - t0:.0f}s)", flush=True)
                if not ok:
                    failures.append((tag, r.stderr[-2000:]))
        if failures:
            for tag, err in failures:
                print("FAILED:", tag, "\n", err)
            sys.exit(1)
        return

    if args.cost_extract:
        res = cost_extract(args.arch, args.shape, args.multi_pod,
                           args.out, opts=opts)
        print(json.dumps(res, indent=1))
        return
    res = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                   args.save_hlo, opts=opts)
    print(json.dumps({k: v for k, v in res.items()
                      if k != "collectives"}, indent=1))
    print("collective bytes/dev:", res["collectives"]["total_bytes"],
          res["collectives"]["counts"])


if __name__ == "__main__":
    main()
