"""musicgen-large [audio]: decoder-only over EnCodec tokens, 4
codebooks (frontend STUB: input_specs supplies token frames).
[arXiv:2306.05284; hf]  48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, act="gelu", num_codebooks=4,
)

SMOKE = CONFIG.scaled(num_layers=3, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=64,
                      num_codebooks=2)
