"""All load-balancing strategies must compute identical fixpoints.

numpy references implement each app independently (Bellman-Ford /
BFS levels / label propagation / iterative peel / power iteration).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph as G
from repro.core.balancer import BalancerConfig, relax, relax_spmd
from repro.core.frontier import single_source
from repro.core import operators as ops
from repro.core.apps import bfs, sssp, cc, pagerank, kcore

STRATS = ["vertex", "twc", "edge_lb", "alb"]


# ---------------- numpy oracles ----------------

def np_csr(g):
    rp = np.asarray(g.row_ptr).astype(np.int64)
    ci = np.asarray(g.col_idx).astype(np.int64)
    w = np.asarray(g.edge_w).astype(np.int64)
    src = np.repeat(np.arange(g.num_vertices), rp[1:] - rp[:-1])
    return rp, ci, w, src


def np_sssp(g, source):
    rp, ci, w, src = np_csr(g)
    dist = np.full(g.num_vertices, int(G.INF), np.int64)
    dist[source] = 0
    for _ in range(g.num_vertices):
        new = dist.copy()
        np.minimum.at(new, ci, dist[src] + w)
        if np.array_equal(new, dist):
            break
        dist = new
    return dist


def np_bfs(g, source):
    rp, ci, w, src = np_csr(g)
    lvl = np.full(g.num_vertices, int(G.INF), np.int64)
    lvl[source] = 0
    for _ in range(g.num_vertices):
        new = lvl.copy()
        np.minimum.at(new, ci, lvl[src] + 1)
        if np.array_equal(new, lvl):
            break
        lvl = new
    return lvl


def np_cc(g):
    rp, ci, w, src = np_csr(g)
    comp = np.arange(g.num_vertices)
    for _ in range(g.num_vertices):
        new = comp.copy()
        np.minimum.at(new, ci, comp[src])
        if np.array_equal(new, comp):
            break
        comp = new
    return comp


def np_kcore(g, k):
    rp, ci, w, src = np_csr(g)
    deg = (rp[1:] - rp[:-1]).copy()
    alive = np.ones(g.num_vertices, bool)
    changed = True
    while changed:
        dead = alive & (deg < k)
        changed = bool(dead.any())
        for v in np.nonzero(dead)[0]:
            alive[v] = False
            deg[ci[rp[v]:rp[v + 1]]] -= 1
    return alive.astype(np.int32)


def np_pagerank(g, damping=0.85, iters=30):
    """Power iteration with dangling (out-degree 0) mass redistributed
    uniformly each round, so sum(rank) == 1 on graphs with sinks."""
    rp, ci, w, src = np_csr(g)
    n = g.num_vertices
    outdeg = rp[1:] - rp[:-1]
    inv = np.where(outdeg > 0, 1.0 / np.maximum(outdeg, 1), 0.0)
    rank = np.full(n, 1.0 / n)
    for _ in range(iters):
        acc = np.zeros(n)
        np.add.at(acc, ci, rank[src] * inv[src])
        dangling = rank[outdeg == 0].sum()
        rank = (1 - damping) / n + damping * (acc + dangling / n)
    return rank


def symmetrize(g):
    rp, ci, w, src = np_csr(g)
    return G.from_edge_list(np.concatenate([src, ci]),
                            np.concatenate([ci, src]), g.num_vertices)


# ---------------- fixtures ----------------

@pytest.fixture(scope="module", params=["rmat", "road", "uniform"])
def graph(request):
    if request.param == "rmat":
        return G.rmat(9, 8, seed=3)
    if request.param == "road":
        return G.road_grid(20, seed=3)
    return G.uniform_random(512, 6, seed=3)


# ---------------- tests ----------------

@pytest.mark.parametrize("strategy", STRATS)
def test_sssp_all_strategies(graph, strategy):
    src = G.highest_out_degree_vertex(graph)
    cfg = BalancerConfig(strategy=strategy, threshold=64)
    out = sssp(graph, src, cfg)
    np.testing.assert_array_equal(np.asarray(out.labels), np_sssp(graph, src))


@pytest.mark.parametrize("strategy", STRATS)
def test_bfs_all_strategies(graph, strategy):
    src = G.highest_out_degree_vertex(graph)
    cfg = BalancerConfig(strategy=strategy, threshold=64)
    out = bfs(graph, src, cfg)
    np.testing.assert_array_equal(np.asarray(out.labels), np_bfs(graph, src))


@pytest.mark.parametrize("strategy", ["twc", "alb"])
def test_cc_strategies(graph, strategy):
    sg = symmetrize(graph)
    cfg = BalancerConfig(strategy=strategy, threshold=64)
    out = cc(sg, cfg)
    np.testing.assert_array_equal(np.asarray(out.labels), np_cc(sg))


@pytest.mark.parametrize("strategy", ["twc", "alb"])
def test_kcore_strategies(graph, strategy):
    sg = symmetrize(graph)
    cfg = BalancerConfig(strategy=strategy, threshold=64)
    out = kcore(sg, 4, cfg)
    np.testing.assert_array_equal(np.asarray(out.labels), np_kcore(sg, 4))


@pytest.mark.parametrize("strategy", ["twc", "alb"])
def test_pagerank_strategies(graph, strategy):
    cfg = BalancerConfig(strategy=strategy, threshold=64)
    out = pagerank(graph, cfg=cfg, max_rounds=30, tol=0.0)
    np.testing.assert_allclose(np.asarray(out.labels),
                               np_pagerank(graph, iters=30), rtol=2e-4)


def test_pagerank_conserves_mass_with_sinks():
    """Regression (dangling vertices): ranks must sum to 1 on a graph
    with sinks.  Before the fix, ``inv_out=0`` rows contributed
    nothing, mass leaked every round and ``tol`` was checked against
    deflated values."""
    # vertices 2 and 3 are sinks (no out-edges)
    g = G.from_edge_list(np.array([0, 0, 1]), np.array([1, 2, 2]), 4)
    out = pagerank(g, max_rounds=60, tol=0.0)
    rank = np.asarray(out.labels)
    assert abs(float(rank.sum()) - 1.0) < 1e-4
    np.testing.assert_allclose(rank, np_pagerank(g, iters=60), rtol=2e-4)


def test_pagerank_unchanged_without_sinks():
    """On a sink-free graph the dangling term is exactly zero, so the
    fix must not perturb results (and mass is conserved as before)."""
    n = 16
    src = np.arange(n)
    g = G.from_edge_list(src, (src + 1) % n, n)     # directed ring
    out = pagerank(g, max_rounds=30, tol=0.0)
    rank = np.asarray(out.labels)
    assert abs(float(rank.sum()) - 1.0) < 1e-4
    np.testing.assert_allclose(rank, np_pagerank(g, iters=30), rtol=2e-4)


def test_driver_loops_make_no_extra_frontier_sync(monkeypatch):
    """Regression (perf): the driver loop must converge from the
    round's own fused host counts (``return_active``) — a separate
    blocking ``jnp.any(frontier)`` per round is one extra device
    round-trip for every host-mode app."""
    from repro.core.apps import drivers as drv
    real_jnp = drv.jnp
    calls = []

    class _SpyJnp:
        def __getattr__(self, name):
            if name == "any":
                calls.append(name)
            return getattr(real_jnp, name)

    monkeypatch.setattr(drv, "jnp", _SpyJnp())
    g = G.road_grid(8, seed=0)
    out = bfs(g, 0)
    assert calls == [], "driver loop still issues jnp.any per round"
    np.testing.assert_array_equal(np.asarray(out.labels), np_bfs(g, 0))
    sg = symmetrize(g)
    calls.clear()
    kc = kcore(sg, 2)
    assert calls == []
    np.testing.assert_array_equal(np.asarray(kc.labels), np_kcore(sg, 2))


def test_cyclic_blocked_same_fixpoint(graph):
    src = G.highest_out_degree_vertex(graph)
    a = sssp(graph, src, BalancerConfig(strategy="alb", threshold=64,
                                        distribution="cyclic"))
    b = sssp(graph, src, BalancerConfig(strategy="alb", threshold=64,
                                        distribution="blocked"))
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))


def test_pallas_path_matches_pure(graph):
    src = G.highest_out_degree_vertex(graph)
    a = sssp(graph, src, BalancerConfig(strategy="alb", threshold=64))
    b = sssp(graph, src, BalancerConfig(strategy="alb", threshold=64,
                                        use_pallas=True))
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))


def test_relax_spmd_matches_host_round(graph):
    """The fully-jit SPMD round equals the host-driven round."""
    src = G.highest_out_degree_vertex(graph)
    v = graph.num_vertices
    dist = jnp.full((v,), G.INF, jnp.int32).at[src].set(0)
    frontier = single_source(v, src)
    cfg = BalancerConfig(strategy="alb", threshold=64)
    host, _ = relax(graph, dist, dist, frontier, cfg, ops.SSSP_RELAX)
    spmd = relax_spmd(graph, dist, dist, frontier, cfg, ops.SSSP_RELAX)
    np.testing.assert_array_equal(np.asarray(host), np.asarray(spmd))


def test_alb_inspector_not_fired_on_flat_graph():
    """road-style graph: the LB executor must never be invoked (the
    paper's 'negligible overhead' claim, Table 2 road-USA rows)."""
    g = G.road_grid(20, seed=0)
    src = 0
    cfg = BalancerConfig(strategy="alb", threshold=64)
    out = sssp(g, src, cfg, collect_stats=True)
    assert all(not st.lb_invoked for st in out.stats)
    assert all(st.edges_lb == 0 for st in out.stats)


def test_alb_inspector_fires_on_power_law():
    g = G.rmat(9, 8, seed=3)
    src = G.highest_out_degree_vertex(g)
    cfg = BalancerConfig(strategy="alb", threshold=64)
    out = sssp(g, src, cfg, collect_stats=True)
    assert any(st.lb_invoked for st in out.stats)


def test_alb_tile_loads_balanced_when_lb_fires():
    """Fig 5 claim: with ALB, per-tile loads of the LB kernel differ by
    at most one edge."""
    g = G.rmat(9, 8, seed=3)
    src = G.highest_out_degree_vertex(g)
    out = sssp(g, src, BalancerConfig(strategy="alb", threshold=64),
               collect_stats=True)
    fired = [st for st in out.stats if st.lb_invoked]
    assert fired
    for st in fired:
        loads = st.tile_loads_lb
        assert loads.max() - loads.min() <= 1
