"""Differential mutation-testing harness for the streaming layer
(DESIGN.md section 10).

The headline guarantee of the streaming subsystem is *bitwise parity*:
after any sequence of edge updates, the incrementally repaired labels
must equal a from-scratch run on the mutated graph — and both must
equal an independent numpy oracle that never touches the jax relax
machinery at all.  The harness replays seeded random mutation traces
(inserts, deletes, reweights, no-ops, in-batch duplicates, padded
slots) through ``stream_update`` across the full strategy x backend x
mode matrix, checking all three sides after every batch.

Also here: the jit cache-miss-counting test (``apply_updates`` and the
repair rounds must never re-trace across batches — the fixed-shape
contract), and the 4-device mirror-sync streaming parity case for the
multidev CI job.
"""
import numpy as np
import jax
import pytest

from repro.core import graph as G
from repro.core import balancer as B
from repro.core import frontier as F
from repro.core import streaming as S
from repro.core.apps import drivers
from repro.core.balancer import BalancerConfig

INF = int(G.INF)
STRATS = ["vertex", "twc", "edge_lb", "alb"]
CAP = 16                      # one batch capacity for every trace
CFG = BalancerConfig(strategy="alb", threshold=64)


# ---------------------------------------------------------------------------
# The oracle: an independent host-side fixpoint over an edge dict.  It
# shares NO code with repro.core — separate label dtype, separate
# iteration scheme, and its own replay of the update tuples — so a bug
# in streaming.py (or in the relax machinery it resumes) cannot cancel
# out of the comparison.
# ---------------------------------------------------------------------------

def oracle_apply(edges, updates):
    """Replay raw update tuples into an edge dict: insert keeps the min
    of duplicates, delete of an absent edge is a no-op, reweight only
    touches existing edges (the documented batch semantics)."""
    for t in updates:
        kind, u, v = t[0], t[1], t[2]
        if kind == "insert":
            w = t[3]
            edges[(u, v)] = min(edges.get((u, v), w), w)
        elif kind == "delete":
            edges.pop((u, v), None)
        elif kind == "reweight":
            if (u, v) in edges:
                edges[(u, v)] = t[3]
        else:                                          # pragma: no cover
            raise AssertionError(t)
    return edges


def oracle_labels(edges, nv, app, source=None):
    """From-scratch min-combine fixpoint on the host (int64 labels,
    dense sweeps via ``np.minimum.at``)."""
    if app == "cc":
        lab = np.arange(nv, dtype=np.int64)
    else:
        lab = np.full(nv, INF, np.int64)
        lab[source] = 0
    if not edges:
        return lab
    es = np.array([k[0] for k in edges], np.int64)
    ed = np.array([k[1] for k in edges], np.int64)
    ew = np.array(list(edges.values()), np.int64)
    while True:
        if app == "bfs":
            msg = np.where(lab[es] < INF, lab[es] + 1, INF)
        elif app == "sssp":
            msg = np.where(lab[es] < INF, lab[es] + ew, INF)
        else:
            msg = lab[es]
        new = lab.copy()
        np.minimum.at(new, ed, msg)
        if np.array_equal(new, lab):
            return lab
        lab = new


# ---------------------------------------------------------------------------
# Seeded random mutation traces.
# ---------------------------------------------------------------------------

def random_trace(rng, edges0, nv, n_batches, max_updates=12):
    """A list of batches, each a list of raw update tuples.  The mix
    deliberately includes semantic no-ops (deleting absent edges,
    reweighting absent edges, re-inserting an edge at a worse weight)
    and in-batch duplicates, and every batch under-fills its capacity
    so padding slots are always exercised."""
    edges = dict(edges0)
    trace = []
    for _ in range(n_batches):
        ups = []
        for _ in range(int(rng.integers(1, max_updates + 1))):
            r = float(rng.random())
            keys = list(edges)
            if r < 0.40 or not keys:
                u, v = int(rng.integers(nv)), int(rng.integers(nv))
                ups.append(("insert", u, v, int(rng.integers(1, 20))))
            elif r < 0.60:
                u, v = keys[int(rng.integers(len(keys)))]
                ups.append(("delete", u, v))
            elif r < 0.75:
                u, v = keys[int(rng.integers(len(keys)))]
                ups.append(("reweight", u, v, int(rng.integers(1, 20))))
            elif r < 0.85:
                # no-op: delete / reweight an (almost surely) absent edge
                u, v = int(rng.integers(nv)), int(rng.integers(nv))
                if (u, v) in edges:
                    continue
                kind = "delete" if rng.random() < 0.5 else "reweight"
                ups.append((kind, u, v) if kind == "delete"
                           else (kind, u, v, int(rng.integers(1, 20))))
            else:
                # in-batch duplicate of the previous update's edge
                if ups:
                    prev = ups[-1]
                    ups.append(("insert", prev[1], prev[2],
                                int(rng.integers(1, 20))))
        edges = oracle_apply(edges, ups)
        trace.append(ups)
    return trace


@pytest.fixture(scope="module")
def base_graph():
    return G.rmat(5, 3, seed=7)          # 32 vertices, ~60 edges


@pytest.fixture(scope="module")
def traces(base_graph):
    """One fixed trace per app, shared by every matrix cell so the 48
    configurations are compared on identical mutation sequences."""
    out = {}
    for i, app in enumerate(S.STREAM_APPS):
        g = G.symmetrized(base_graph) if app == "cc" else base_graph
        rng = np.random.default_rng(100 + i)
        out[app] = random_trace(rng, S.edge_map(g), g.num_vertices,
                                n_batches=3)
    return out


def _replay_and_check(g0, app, cfg, mode, trace):
    """The differential core: replay a trace through stream_update,
    asserting after EVERY batch that the maintained labels match (a)
    the numpy oracle and (b) a from-scratch driver run on the mutated
    graph — bitwise, over the real-vertex slice."""
    nv = g0.num_vertices
    source = None if app == "cc" else G.highest_out_degree_vertex(g0)
    st = S.stream_init(S.streaming_graph(g0), app, source=source,
                       cfg=cfg, mode=mode)
    edges = dict(S.edge_map(st.g))
    for ups in trace:
        batch = S.make_batch(ups, capacity=CAP)
        report = S.stream_update(st, batch)
        edges = oracle_apply(edges, ups)
        want = oracle_labels(edges, nv, app, source)
        got = st.real_labels.astype(np.int64)
        np.testing.assert_array_equal(got, want)
        ref = S._full_compute(st.g, app, source, cfg, mode).labels
        np.testing.assert_array_equal(
            st.real_labels, np.asarray(ref)[:nv])
        assert report.version == st.g.version


# ---------------------------------------------------------------------------
# The 48-cell matrix: 3 apps x 4 strategies x {xla, pallas} x
# {host, spmd}.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["host", "spmd"])
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("strategy", STRATS)
@pytest.mark.parametrize("app", sorted(S.STREAM_APPS))
def test_differential_matrix(base_graph, traces, app, strategy,
                             use_pallas, mode):
    g0 = G.symmetrized(base_graph) if app == "cc" else base_graph
    cfg = BalancerConfig(strategy=strategy, threshold=64,
                         use_pallas=use_pallas)
    _replay_and_check(g0, app, cfg, mode, traces[app])


@pytest.mark.parametrize("mode", ["host", "spmd"])
@pytest.mark.parametrize("direction", ["pull", "adaptive"])
@pytest.mark.parametrize("app", ["bfs", "sssp"])
def test_differential_directions(base_graph, traces, app, direction,
                                 mode):
    """Repair rounds under pull/adaptive traversal (push is the matrix
    default above): the version-keyed reverse()/pull-enum caches must
    rebuild per mutation, or these would relax the stale transpose."""
    cfg = BalancerConfig(strategy="alb", threshold=64,
                         direction=direction)
    _replay_and_check(base_graph, app, cfg, mode, traces[app])


# ---------------------------------------------------------------------------
# Directed edge cases (single config: they exercise streaming.py
# classification logic, which is strategy-independent).
# ---------------------------------------------------------------------------

def test_empty_batch_is_zero_rounds(base_graph):
    st = S.stream_init(S.streaming_graph(base_graph), "bfs", source=0,
                       cfg=CFG)
    v0 = st.version
    before = st.real_labels.copy()
    rep = S.stream_update(st, S.make_batch([], capacity=CAP))
    assert rep.rounds == 0 and rep.seeds == 0 and not rep.full_recompute
    assert st.version == v0 + 1           # version still advances
    np.testing.assert_array_equal(st.real_labels, before)


def test_noop_batch_is_zero_rounds(base_graph):
    st = S.stream_init(S.streaming_graph(base_graph), "sssp", source=0,
                       cfg=CFG)
    em = S.edge_map(st.g)
    (u, v), w = next(iter(em.items()))
    absent = next((a, b) for a in range(st.g.num_vertices)
                  for b in range(st.g.num_vertices)
                  if (a, b) not in em)
    rep = S.stream_update(st, S.make_batch([
        ("insert", u, v, w + 5),          # worse duplicate: min keeps w
        ("delete", absent[0], absent[1]),  # absent: no-op
        ("reweight", absent[0], absent[1], 3),
    ], capacity=CAP))
    assert rep.rounds == 0 and rep.seeds == 0 and not rep.full_recompute


def test_reweight_is_noop_for_weight_blind_apps(base_graph):
    g = G.symmetrized(base_graph)
    for app, source in (("bfs", 0), ("cc", None)):
        st = S.stream_init(S.streaming_graph(g), app, source=source,
                           cfg=CFG)
        em = S.edge_map(st.g)
        (u, v), w = next(iter(em.items()))
        rep = S.stream_update(st, S.make_batch(
            [("reweight", u, v, w + 17)], capacity=CAP))
        assert rep.rounds == 0 and not rep.full_recompute, app


def test_tight_delete_forces_full_recompute(base_graph):
    src = G.highest_out_degree_vertex(base_graph)
    st = S.stream_init(S.streaming_graph(base_graph), "sssp",
                       source=src, cfg=CFG)
    lab = st.real_labels
    em = S.edge_map(st.g)
    tight = next((u, v) for (u, v), w in em.items()
                 if lab[u] < INF and lab[u] + w == lab[v])
    rep = S.stream_update(st, S.make_batch(
        [("delete", tight[0], tight[1])], capacity=CAP))
    assert rep.full_recompute
    ref = drivers.sssp(st.g, src, CFG).labels
    np.testing.assert_array_equal(
        st.real_labels, np.asarray(ref)[:base_graph.num_vertices])


def test_slack_delete_stays_incremental(base_graph):
    src = G.highest_out_degree_vertex(base_graph)
    st = S.stream_init(S.streaming_graph(base_graph), "sssp",
                       source=src, cfg=CFG)
    lab = st.real_labels
    em = S.edge_map(st.g)
    slack = next(((u, v) for (u, v), w in em.items()
                  if not (lab[u] < INF and lab[u] + w == lab[v])), None)
    if slack is None:
        pytest.skip("no slack edge in this graph")
    rep = S.stream_update(st, S.make_batch(
        [("delete", slack[0], slack[1])], capacity=CAP))
    assert not rep.full_recompute and rep.rounds == 0
    ref = drivers.sssp(st.g, src, CFG).labels
    np.testing.assert_array_equal(
        st.real_labels, np.asarray(ref)[:base_graph.num_vertices])


def test_update_validation(base_graph):
    g = S.streaming_graph(base_graph)
    nv_real = S.real_vertices(g)
    with pytest.raises(ValueError, match="out of range"):
        S.apply_updates(g, S.make_batch([("insert", 0, nv_real, 1)]))
    with pytest.raises(ValueError, match="weight"):
        S.apply_updates(g, S.make_batch([("insert", 0, 1, 0)]))
    with pytest.raises(ValueError, match="streaming-enabled"):
        S.apply_updates(base_graph, S.make_batch([("insert", 0, 1, 1)]))
    with pytest.raises(ValueError, match="capacity"):
        S.make_batch([("insert", 0, 1, 1)] * 5, capacity=4)


def test_in_place_update_bumps_version_and_repairs(base_graph):
    """in_place=True mutates the SAME Graph object: every reference
    observes the new topology and the bumped version."""
    st = S.stream_init(S.streaming_graph(base_graph), "bfs", source=0,
                       cfg=CFG)
    g_ref = st.g
    v0 = g_ref.version
    far = int(np.argmax(st.real_labels))  # worst-reached vertex
    S.stream_update(st, S.make_batch([("insert", 0, far, 1)],
                                     capacity=CAP), in_place=True)
    assert st.g is g_ref and g_ref.version == v0 + 1
    assert (far, ) and st.real_labels[far] == 1
    ref = drivers.bfs(g_ref, 0, CFG).labels
    np.testing.assert_array_equal(
        st.real_labels, np.asarray(ref)[:base_graph.num_vertices])


def test_capacity_overflow_grows_edge_array():
    # 64 vertices so >1024 distinct edges exist to overflow the
    # minimum edge bucket
    g = S.streaming_graph(G.uniform_random(64, avg_degree=4, seed=3))
    ecap0 = g.num_edges
    nv = S.real_vertices(g)
    rng = np.random.default_rng(3)
    ups = []
    seen = set(S.edge_map(g))
    while len(seen) < ecap0 + 1:                 # force past capacity
        u, v = int(rng.integers(nv)), int(rng.integers(nv))
        if (u, v) not in seen:
            seen.add((u, v))
            ups.append(("insert", u, v, 1))
    g2 = S.apply_updates(g, S.make_batch(ups))
    assert g2.num_edges > ecap0
    assert len(S.edge_map(g2)) == len(seen)


# ---------------------------------------------------------------------------
# The fixed-shape contract: update/repair cycles never re-trace.
# ---------------------------------------------------------------------------

def test_apply_updates_never_recompiles(base_graph):
    """After warmup, arbitrarily many update/repair cycles — hitting
    both the incremental path and the full-recompute fallback, in both
    execution modes — add ZERO entries to any jitted round function's
    trace cache: the acceptance criterion of DESIGN.md section 10."""
    src = G.highest_out_degree_vertex(base_graph)
    states = [S.stream_init(S.streaming_graph(base_graph), "sssp",
                            source=src, cfg=CFG, mode=m)
              for m in ("host", "spmd")]
    rng = np.random.default_rng(42)
    nv = base_graph.num_vertices

    def cycle(st):
        trace = random_trace(rng, S.edge_map(st.g), nv, n_batches=2)
        for ups in trace:
            S.stream_update(st, S.make_batch(ups, capacity=CAP))

    for st in states:                     # warmup traces every shape
        cycle(st)
        # force the delete-fallback path once too
        lab = st.real_labels
        em = S.edge_map(st.g)
        tight = next(((u, v) for (u, v), w in em.items()
                      if lab[u] < INF and lab[u] + w == lab[v]), None)
        if tight is not None:
            S.stream_update(st, S.make_batch([("delete", *tight)],
                                             capacity=CAP))

    watched = {
        "host_round_counts": B._host_round_counts,
        "bin_pass": B._bin_pass,
        "lb_pass": B._lb_pass,
        "gather_bin": B._gather_bin,
        "relax_spmd": B.relax_spmd,
        "compact": F.compact,
        "seed_from_edges": F.seed_from_edges,
    }
    sizes = {k: f._cache_size() for k, f in watched.items()}
    assert sizes["seed_from_edges"] >= 1  # the seeding scatter traced

    for _ in range(3):
        for st in states:
            cycle(st)

    after = {k: f._cache_size() for k, f in watched.items()}
    assert after == sizes, (sizes, after)


# ---------------------------------------------------------------------------
# Optional hypothesis sweep (the container may not ship hypothesis;
# the seeded-RNG matrix above is the tier-1 guarantee either way).
# ---------------------------------------------------------------------------

def test_hypothesis_random_updates(base_graph):
    hyp = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed")
    from hypothesis import given, settings, strategies as hst

    nv = base_graph.num_vertices
    update = hst.one_of(
        hst.tuples(hst.just("insert"), hst.integers(0, nv - 1),
                   hst.integers(0, nv - 1), hst.integers(1, 30)),
        hst.tuples(hst.just("delete"), hst.integers(0, nv - 1),
                   hst.integers(0, nv - 1)),
        hst.tuples(hst.just("reweight"), hst.integers(0, nv - 1),
                   hst.integers(0, nv - 1), hst.integers(1, 30)))

    @settings(max_examples=20, deadline=None)
    @given(hst.lists(hst.lists(update, max_size=CAP), max_size=3))
    def check(trace):
        st = S.stream_init(S.streaming_graph(base_graph), "sssp",
                           source=0, cfg=CFG)
        edges = dict(S.edge_map(st.g))
        for ups in trace:
            S.stream_update(st, S.make_batch(ups, capacity=CAP))
            edges = oracle_apply(edges, ups)
            np.testing.assert_array_equal(
                st.real_labels.astype(np.int64),
                oracle_labels(edges, nv, "sssp", 0))

    check()


# ---------------------------------------------------------------------------
# 4-device mirror-sync streaming parity (multidev CI job).
# ---------------------------------------------------------------------------

NDEV = 4
multidevice = pytest.mark.skipif(
    len(jax.devices()) < NDEV,
    reason=f"needs {NDEV} devices (CI sets "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={NDEV})")


@multidevice
@pytest.mark.parametrize("policy", ["oec", "cvc"])
def test_streaming_labels_match_mirror_sync(base_graph, traces, policy):
    """After a mutation trace, the incrementally maintained labels must
    equal a distributed mirror-sync BFS over the mutated graph: the
    streaming layer and the Gluon substrate agree on what the current
    topology's fixpoint is."""
    from repro.core.partition import partition
    from repro.core import gluon

    src = G.highest_out_degree_vertex(base_graph)
    st = S.stream_init(S.streaming_graph(base_graph), "bfs",
                       source=src, cfg=CFG)
    for ups in traces["bfs"]:
        S.stream_update(st, S.make_batch(ups, capacity=CAP))

    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(S.unpadded(st.g), NDEV, policy)
    labels, _, _, _ = gluon.bfs_distributed(
        sg, mesh, src, CFG, collect_stats=True, sync="mirror", meta=meta)
    nv = base_graph.num_vertices
    np.testing.assert_array_equal(np.asarray(labels)[:nv],
                                  st.real_labels)
