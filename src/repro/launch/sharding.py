"""Sharding rules: param/cache/activation PartitionSpecs per arch.

Megatron TP over ``model`` + FSDP-style parameter sharding over
``data``; the ``pod`` axis carries pure data parallelism (params
replicated across pods, gradients reduced over (pod, data)).

Rules are path-based: each param leaf name maps to a spec for its
TRAILING dims; leading dims (layer stacks, hybrid groups, codebooks,
expert stacks handled explicitly) are padded with None.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P, NamedSharding


# trailing-dims spec per leaf name (non-MoE-expert params)
_BASE_RULES = {
    # embeddings / heads
    "embed": ("model", "data"),
    "lm_head": ("data", "model"),
    # attention (gqa)
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    # attention (mla)
    "wq_a": ("data", None),
    "wq_b": (None, "model"),
    "wkv_a": ("data", None),
    "wkv_b": (None, "model"),
    "q_norm": (None,),
    "kv_norm": (None,),
    # mlp
    "w_up": ("data", "model"),
    "w_gate": ("data", "model"),
    "w_down": ("model", "data"),
    # moe router
    "router": ("data", None),
    # mamba2
    "w_in": ("data", "model"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "a_log": (None,),
    "dt_bias": (None,),
    "d_skip": (None,),
    "out_norm": ("model",),
    "w_out": ("model", "data"),
    # norms
    "norm": (None,),
    "norm1": (None,),
    "norm2": (None,),
    "final_norm": (None,),
}

# expert-stacked MoE params: leading E dim is the expert-parallel axis
_MOE_EXPERT_RULES = {
    "w_gate": ("model", None, None),
    "w_up": ("model", None, None),
    "w_down": ("model", None, None),
}


def _path_str(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _spec_for(path, leaf):
    pstr = _path_str(path)
    name = pstr.split("/")[-1]
    in_moe = "/moe/" in f"/{pstr}/" and "/shared/" not in f"/{pstr}/"
    if in_moe and name in _MOE_EXPERT_RULES:
        base = _MOE_EXPERT_RULES[name]
    elif name in _BASE_RULES:
        base = _BASE_RULES[name]
    else:
        base = ()
    pad = leaf.ndim - len(base)
    assert pad >= 0, f"{pstr}: rank {leaf.ndim} < rule {base}"
    return P(*((None,) * pad + tuple(base)))


_MOE_EXPERT_FSDP_RULES = {
    # H1: experts additionally FSDP-sharded over data on d_model
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def param_specs(params_shape, expert_fsdp: bool = False):
    """PartitionSpec pytree matching a params (shape) pytree."""
    def spec(path, leaf):
        if expert_fsdp:
            pstr = _path_str(path)
            name = pstr.split("/")[-1]
            in_moe = "/moe/" in f"/{pstr}/" and "/shared/" not in                 f"/{pstr}/"
            if in_moe and name in _MOE_EXPERT_FSDP_RULES:
                base = _MOE_EXPERT_FSDP_RULES[name]
                pad = leaf.ndim - len(base)
                return P(*((None,) * pad + tuple(base)))
        return _spec_for(path, leaf)
    return jax.tree_util.tree_map_with_path(spec, params_shape)


def opt_specs(params_spec, master_weights: bool = False):
    """Optimizer state mirrors param sharding; step is replicated."""
    out = {"mu": params_spec, "nu": params_spec, "step": P()}
    if master_weights:
        out["master"] = params_spec
    return out


def dp_axes_for(multi_pod: bool, global_batch: int):
    """Batch axes actually usable: long-context cells with batch 1
    cannot shard batch — fall back to replication (TP-only posture)."""
    dp = ("pod", "data") if multi_pod else ("data",)
    size = 32 if multi_pod else 16
    return dp if global_batch % size == 0 else None


def batch_specs(multi_pod: bool, num_codebooks: int = 1,
                with_prefix: bool = False, global_batch: int = 0):
    dp = dp_axes_for(multi_pod, global_batch) if global_batch \
        else (("pod", "data") if multi_pod else "data")
    tok = P(dp, None) if num_codebooks == 1 else P(dp, None, None)
    out = {"tokens": tok, "labels": tok}
    if with_prefix:
        out["prefix_emb"] = P(dp, None, None)
    return out


def cache_specs(cfg, multi_pod: bool, global_batch: int = 0,
                seq_len: int = 0, model_size: int = 16):
    """Decode-state sharding: batch over data axes; heads over model
    when the head count divides the model axis, else the SEQUENCE dim
    (sequence-parallel KV cache — the GQA-few-heads / MQA fallback)."""
    dp = dp_axes_for(multi_pod, global_batch) if global_batch \
        else (("pod", "data") if multi_pod else "data")
    kv_ok = cfg.num_kv_heads % model_size == 0 and cfg.num_kv_heads > 0
    seq_ok = seq_len % model_size == 0 and seq_len > 0

    def leaf_spec(path, leaf):
        name = _path_str(path).split("/")[-1]
        if name == "index":
            return P()
        nd = leaf.ndim
        if name in ("k", "v"):            # [L?, B, S, Hkv, hd]
            if kv_ok:
                base = (dp, None, "model", None)
            elif seq_ok:
                base = (dp, "model", None, None)
            else:
                base = (dp, None, None, None)
        elif name == "ckv":               # [L, B, S, r]
            base = (dp, "model" if seq_ok else None, None)
        elif name == "k_rope":            # [L, B, S, 1, rope]
            base = (dp, "model" if seq_ok else None, None, None)
        elif name == "h":                 # [G?, L?, B, H, P, N]
            base = (dp, "model", None, None)
        elif name == "conv":              # [G?, L?, B, k-1, C]
            base = (dp, None, "model")
        else:
            base = (dp,)
        pad = nd - len(base)
        return P(*((None,) * pad + tuple(base)))

    import repro.models.transformer as T
    shapes = T.init_cache(cfg, 1, 1)
    return jax.tree_util.tree_map_with_path(leaf_spec, shapes)


def make_shard_fn(mesh, multi_pod: bool, seqpar: bool = False,
                  moe_data: bool = False, dp_override=...):
    """Activation constrainer injected into the model.

    seqpar (H3): residual-stream activations are sharded over `model`
    on the SEQUENCE dim between blocks (Megatron sequence parallelism)
    so GSPMD replaces the per-block all-reduce with a reduce-scatter +
    all-gather pair — half the bytes on the wire.
    """
    dp = (("pod", "data") if multi_pod else "data")         if dp_override is ... else dp_override
    model_size = mesh.shape["model"]

    data_size = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)

    def shard_fn(name, x):
        if name == "moe_tok":
            # [G, TgK, D] / [G, TgK]: group dim rides the data axes
            if x.shape[0] % data_size == 0 and x.shape[0] > 1:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(
                        mesh, P(dp, *([None] * (x.ndim - 1)))))
            return x
        if name == "moe_buf":
            if x.ndim == 4:
                # grouped dispatch [G, E, C, D]: groups ride data,
                # experts ride model
                if x.shape[0] % data_size == 0 or x.shape[0] == 1:
                    gspec = dp if x.shape[0] > 1 else None
                    return jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh,
                                         P(gspec, "model", None, None)))
                return x
            # ungrouped [E, C, D] + moe_data: capacity dim over data
            if moe_data and x.shape[1] % data_size == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P("model", dp, None)))
            return x
        if (seqpar and x.ndim == 3 and x.shape[1] > 1
                and x.shape[1] % model_size == 0):
            spec = P(dp, "model", None)
        elif x.ndim >= 3:
            spec = P(dp, *([None] * (x.ndim - 1)))
        else:
            spec = P(dp, None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return shard_fn
