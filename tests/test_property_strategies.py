"""Property-based tests of the system's core invariant: every
load-balancing strategy computes the identical fixpoint on ANY graph
(the balancer only changes the work schedule, never the semantics)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import graph as G
from repro.core.balancer import BalancerConfig
from repro.core.apps import sssp, cc


@st.composite
def random_graph(draw):
    n = draw(st.integers(4, 48))
    m = draw(st.integers(0, 160))
    src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    w = draw(st.lists(st.integers(1, 20), min_size=m, max_size=m))
    return G.from_edge_list(np.asarray(src, np.int64),
                            np.asarray(dst, np.int64), n,
                            weights=np.asarray(w, np.int32))


@settings(max_examples=15, deadline=None)
@given(g=random_graph(), threshold=st.sampled_from([4, 16, 64]),
       dist=st.sampled_from(["cyclic", "blocked"]))
def test_all_strategies_same_sssp_fixpoint(g, threshold, dist):
    if g.num_edges == 0:
        return
    src = G.highest_out_degree_vertex(g)
    ref = None
    for strat in ["vertex", "twc", "edge_lb", "alb"]:
        cfg = BalancerConfig(strategy=strat, threshold=threshold,
                             distribution=dist, small_width=8,
                             medium_width=16)
        out = np.asarray(sssp(g, src, cfg).labels)
        if ref is None:
            ref = out
        else:
            np.testing.assert_array_equal(out, ref, err_msg=strat)


@settings(max_examples=10, deadline=None)
@given(g=random_graph())
def test_cc_labels_are_valid_components(g):
    """Property: after cc on the symmetrized graph, every edge joins
    two vertices with the same label, and labels are component minima."""
    rp = np.asarray(g.row_ptr).astype(np.int64)
    ci = np.asarray(g.col_idx).astype(np.int64)
    src = np.repeat(np.arange(g.num_vertices), rp[1:] - rp[:-1])
    sym = G.from_edge_list(np.concatenate([src, ci]),
                           np.concatenate([ci, src]), g.num_vertices)
    labels = np.asarray(cc(sym, BalancerConfig(strategy="alb",
                                               threshold=16)).labels)
    srp = np.asarray(sym.row_ptr).astype(np.int64)
    sci = np.asarray(sym.col_idx).astype(np.int64)
    ssrc = np.repeat(np.arange(sym.num_vertices), srp[1:] - srp[:-1])
    assert (labels[ssrc] == labels[sci]).all()
    # each label is the smallest vertex id in its set
    for lbl in np.unique(labels):
        members = np.nonzero(labels == lbl)[0]
        assert members.min() == lbl
