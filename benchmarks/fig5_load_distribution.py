"""Fig 1/5 analogue: per-tile ("thread block") edge-load distribution
with and without ALB, round by round."""
from __future__ import annotations

import numpy as np

from repro.core.balancer import BalancerConfig
from repro.core import graph as G
from repro.core.apps import sssp

from .common import bench_graphs, emit


def imbalance(loads: np.ndarray) -> float:
    mean = max(loads.mean(), 1.0)
    return float(loads.max() / mean)


def run(scale: int = 13):
    g = bench_graphs(scale)["rmat"]
    src = G.highest_out_degree_vertex(g)
    out = {}
    for strat in ["twc", "alb"]:
        cfg = BalancerConfig(strategy=strat, threshold=1024)
        res = sssp(g, src, cfg, collect_stats=True)
        for rnd, st in enumerate(res.stats[:4]):
            total = st.tile_loads_twc + st.tile_loads_lb
            imb = imbalance(total)
            out[(strat, rnd)] = imb
            emit(f"fig5/{strat}/round{rnd}", res.seconds,
                 f"imbalance={imb:.1f} edges_twc={st.edges_twc} "
                 f"edges_lb={st.edges_lb} lb_fired={st.lb_invoked}")
    return out


if __name__ == "__main__":
    run()
