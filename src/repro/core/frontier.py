"""Worklist (frontier) utilities.

The paper's D-IrGL baseline uses *implicit dense worklists* (a boolean
flag per vertex, Section 6.1); the GPU kernels are launched per round
with runtime-sized geometry.  We mirror both:

* dense frontier: ``bool[V]`` mask,
* compacted frontier: ``int32[F]`` vertex indices, padded with ``V``
  (an out-of-range sentinel, dropped by ``mode='drop'`` scatters), where
  ``F`` is a *bucketed* capacity so the per-round jitted functions are
  reused across rounds (the CPU/GPU analogue of launching a kernel with
  runtime grid size).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def next_bucket(n: int, minimum: int = 64) -> int:
    """Smallest power of two >= max(n, minimum). Bounds re-jit count."""
    n = max(int(n), minimum)
    return 1 << (n - 1).bit_length()


@partial(jax.jit, static_argnames=("size",))
def compact(mask: jax.Array, size: int) -> jax.Array:
    """Indices of set bits, padded with len(mask) (sentinel)."""
    return jnp.nonzero(mask, size=size, fill_value=mask.shape[0])[0]


@jax.jit
def count(mask: jax.Array) -> jax.Array:
    return jnp.sum(mask.astype(jnp.int32))


@jax.jit
def dirty_mask(old: jax.Array, new: jax.Array) -> jax.Array:
    """Per-vertex "label touched this round" bitvector (Gluon's dirty
    set): the master/mirror sync only exchanges vertices set here
    (DESIGN.md section 6)."""
    return new != old


def full_frontier(num_vertices: int) -> jax.Array:
    return jnp.ones((num_vertices,), dtype=bool)


def single_source(num_vertices: int, src: int) -> jax.Array:
    return jnp.zeros((num_vertices,), dtype=bool).at[src].set(True)
