"""The SLO-aware multi-replica serving fleet (DESIGN.md section 13).

:class:`Fleet` scales the continuous-batching
:class:`~repro.serve.engine.QueryService` one level up: N engine
replicas (optionally pinned across devices, all serving every
registered graph) behind a router that composes cache-affinity
rendezvous hashing, bounded-load redirection, and
power-of-two-choices admission scored by a tail-risk estimate — with
SLO-conditional hedging of stragglers and cancel-on-first-finish.
Every executed routing decision is recorded into a replayable
:class:`~repro.serve.fleet.trace.RoutingTrace`; because
:func:`~repro.serve.fleet.router.decide` is pure over the recorded
inputs, the whole run's routing can be re-derived offline and
compared bitwise (the fleet's determinism witness).

Determinism end to end: replica stepping order is fixed, the P2C
sampler is a seeded generator whose draws are recorded as decision
inputs, every replica result is bitwise equal to its standalone run
(the engine's parity invariant), and the winning finisher of a hedged
pair is published through :func:`repro.serve.publish.freeze` exactly
once — the loser is cancelled, or dropped if it finished in the same
step, never double-published.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.balancer import BalancerConfig

from ..engine import QueryService
from ..queue import DONE, RUNNING
from ..publish import freeze
from .replica import ReplicaHandle
from .router import (RouterConfig, DecisionInputs, decide,
                     rendezvous_order, load_ceiling,
                     FeedbackController)
from .hedge import HedgePolicy, hedgeable
from .trace import RoutingTrace


@dataclasses.dataclass
class FleetQuery:
    """One fleet-level query and its full lifecycle record: the
    replica submissions fanned out for it (primary first, then
    hedges), the winner, and the published result."""
    fqid: int
    graph_id: str
    app: str
    source: int
    status: str = RUNNING           # running | done (fleet-level)
    result: Optional[np.ndarray] = None
    from_cache: bool = False
    submit_step: int = 0
    done_step: Optional[int] = None
    submissions: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)      # (replica id, replica qid)
    winner: Optional[int] = None    # replica id that answered
    hedges: int = 0

    @property
    def steps_in_system(self) -> Optional[int]:
        """Fleet steps from submission to publication (0 for a hit
        answered at submission)."""
        if self.done_step is None:
            return None
        return self.done_step - self.submit_step


class Fleet:
    """N :class:`QueryService` replicas behind the adaptive router.

    ``num_replicas`` engine replicas are built from the same
    ``cfg``/``mode``/``num_slots`` (the per-replica knobs of
    :class:`QueryService`); ``devices`` optionally pins replica i to
    ``devices[i % len(devices)]``; ``router``/``hedge`` configure the
    policy; ``seed`` fixes the P2C sampler, so identical submission
    sequences produce identical routing traces run to run.

    Typical use::

        fleet = Fleet(num_replicas=3, num_slots=4)
        fleet.register_graph("social", g)
        fqid = fleet.submit("social", "bfs", source=17)
        fleet.run()                      # drain all replicas
        labels = fleet.poll(fqid).result # bitwise == bfs(g, 17)
        assert not trace_replay(fleet)   # every decision re-derivable
    """

    def __init__(self, num_replicas: int = 3,
                 cfg: BalancerConfig = BalancerConfig(),
                 num_slots: int = 4,
                 mode: str = "host",
                 round_budget: Optional[int] = None,
                 cache_capacity: int = 256,
                 router: RouterConfig = RouterConfig(),
                 hedge: HedgePolicy = HedgePolicy(),
                 devices: Optional[list] = None,
                 seed: int = 0) -> None:
        if num_replicas < 1:
            raise ValueError("num_replicas must be >= 1")
        self.router_cfg = router
        self.hedge_policy = hedge
        self.controller = FeedbackController(router)
        self.replicas: List[ReplicaHandle] = []
        for rid in range(num_replicas):
            dev = (devices[rid % len(devices)]
                   if devices else None)
            self.replicas.append(ReplicaHandle(
                rid,
                QueryService(num_slots=num_slots, cfg=cfg, mode=mode,
                             round_budget=round_budget,
                             cache_capacity=cache_capacity),
                device=dev))
        self.trace = RoutingTrace()
        self._rng = np.random.default_rng(seed)
        self._records: Dict[int, FleetQuery] = {}
        self._loads = [0] * num_replicas   # assigned in-flight per
        #                                    replica (the bounded-load
        #                                    quantity)
        self._next_fqid = 0
        self._step = 0
        self._seq = 0
        self.hedges_launched = 0
        self.hedges_cancelled = 0

    # ---- graph registry --------------------------------------------------

    def register_graph(self, graph_id: str, g: Graph) -> None:
        """Bind ``graph_id`` on EVERY replica: any replica can serve
        any registered graph (affinity only concentrates repeats, it
        never partitions correctness)."""
        for rep in self.replicas:
            rep.svc.register_graph(graph_id, g)

    # ---- routing ---------------------------------------------------------

    def _scores(self) -> Tuple[float, ...]:
        """Live tail-risk score per replica: assigned load plus the
        controller-weighted rounds-remaining EWMA and queue-head age
        (the ALPHA1 composite, DESIGN.md section 13)."""
        c = self.controller
        return tuple(
            float(self._loads[r.rid]
                  + c.w_tail * r.rounds_remaining()
                  + c.w_age * r.queue_head_age())
            for r in self.replicas)

    def _sample_pair(self, allowed: List[int]) -> Tuple[int, ...]:
        """Draw the P2C candidates from ``allowed`` (2 when possible,
        1 when only one replica is eligible).  The draw is consumed
        here; the SAMPLED PAIR is what enters the trace, so replay
        never needs the generator state."""
        if len(allowed) == 1:
            return (allowed[0],)
        picks = self._rng.choice(len(allowed), size=2, replace=False)
        return tuple(sorted(allowed[int(i)] for i in picks))

    def _route(self, fqid: int, key: tuple, kind: str,
               exclude: Tuple[int, ...] = ()) -> Tuple[int, str]:
        """Build the decision inputs, decide, and record the executed
        decision into the trace."""
        allowed = [r.rid for r in self.replicas
                   if r.rid not in exclude]
        inputs = DecisionInputs(
            seq=self._seq, fqid=fqid, kind=kind, key=key,
            loads=tuple(self._loads), scores=self._scores(),
            order=rendezvous_order(key, len(self.replicas)),
            pair=self._sample_pair(allowed),
            capacity_factor=self.router_cfg.capacity_factor,
            affinity=self.router_cfg.affinity, exclude=exclude)
        choice, reason = decide(inputs)
        if kind == "hedge":
            # capacity-conditional: a hedge that would break the
            # bounded-load ceiling is skipped, not forced
            ceil_ = load_ceiling(inputs.loads,
                                 inputs.capacity_factor)
            if inputs.loads[choice] + 1 > ceil_:
                return -1, "skipped"
        self.trace.append(inputs, choice, reason)
        self._seq += 1
        return choice, reason

    # ---- submit / poll ---------------------------------------------------

    def submit(self, graph_id: str, app: str, source: int) -> int:
        """Route one point query into the fleet; returns its fleet
        qid.  A replica-level cache hit (LRU or single-flight answered
        at submission) completes the fleet record immediately."""
        fqid = self._next_fqid
        self._next_fqid += 1
        key = (graph_id, app, int(source))
        rec = FleetQuery(fqid=fqid, graph_id=graph_id, app=app,
                         source=int(source), submit_step=self._step)
        self._records[fqid] = rec
        rid, _ = self._route(fqid, key, kind="route")
        rqid = self.replicas[rid].svc.submit(graph_id, app, source)
        rec.submissions.append((rid, rqid))
        q = self.replicas[rid].svc.poll(rqid)
        if q.status == DONE:                   # answered at submission
            self._publish(rec, rid, q)
        else:
            self._loads[rid] += 1
        return fqid

    def poll(self, fqid: int) -> FleetQuery:
        """The fleet query's live record (status, result, winner,
        hedges)."""
        return self._records[fqid]

    # ---- the fleet loop --------------------------------------------------

    def step(self) -> bool:
        """One fleet round: advance every replica (honoring straggler
        throttles), publish first finishers and cancel their losers,
        launch due hedges, and run one feedback-controller update.
        Returns False when nothing is left in flight anywhere."""
        self._step += 1
        did_work = False
        for rep in self.replicas:
            did_work |= rep.step()
        self._collect()
        self._maybe_hedge()
        self.controller.update(self._aggregate_p95())
        inflight = any(rec.status == RUNNING
                       for rec in self._records.values())
        return did_work or inflight

    def run(self, max_steps: int = 1_000_000) -> dict:
        """Drain: step until every fleet query is published (bounded
        by ``max_steps`` as a divergence guard).  Returns
        :meth:`summary`."""
        for _ in range(max_steps):
            if not self.step():
                return self.summary()
        raise RuntimeError(f"fleet did not drain in {max_steps} steps")

    # ---- internals -------------------------------------------------------

    def _publish(self, rec: FleetQuery, rid: int, q) -> None:
        """Publish the FIRST finisher of a fleet query through the
        freeze() choke point and retire every other submission: still-
        running losers are cancelled, an already-finished loser is
        dropped here — either way the record is published exactly
        once."""
        labels = freeze(q.result)
        rec.result = labels
        rec.status = DONE
        rec.from_cache = q.from_cache
        rec.done_step = self._step
        rec.winner = rid
        for orid, orqid in rec.submissions:
            if orid == rid:          # each replica holds a query at
                continue             # most once (hedges exclude
            #                          holders), so rid IDs the winner
            if self.replicas[orid].svc.cancel(orqid):
                self.hedges_cancelled += 1
            self._loads[orid] -= 1

    def _collect(self) -> None:
        """Publish every in-flight record whose submissions include a
        finisher (submission order breaks same-step ties, so the
        primary wins deterministically when both land together)."""
        for rec in self._records.values():
            if rec.status != RUNNING:
                continue
            for rid, rqid in rec.submissions:
                q = self.replicas[rid].svc.poll(rqid)
                if q.status == DONE:
                    self._loads[rid] -= 1
                    self._publish(rec, rid, q)
                    break

    def _maybe_hedge(self) -> None:
        """Launch a hedge for every SLO-late record that still has a
        replica not holding it (and capacity under the ceiling)."""
        for rec in self._records.values():
            if not hedgeable(rec, self._step,
                             self.controller.hedge_after,
                             self.hedge_policy):
                continue
            holding = tuple(rid for rid, _ in rec.submissions)
            if len(holding) >= len(self.replicas):
                continue
            key = (rec.graph_id, rec.app, rec.source)
            rid, _ = self._route(rec.fqid, key, kind="hedge",
                                 exclude=holding)
            if rid < 0:                        # ceiling-skipped
                continue
            rqid = self.replicas[rid].svc.submit(
                rec.graph_id, rec.app, rec.source)
            rec.submissions.append((rid, rqid))
            rec.hedges += 1
            self.hedges_launched += 1
            q = self.replicas[rid].svc.poll(rqid)
            if q.status == DONE:               # hedge hit a warm cache
                self._publish(rec, rid, q)
            else:
                self._loads[rid] += 1

    def _aggregate_p95(self) -> float:
        """Fleet-wide p95 rounds-in-system aggregated from every
        replica's ServiceStats (the controller's feedback signal).
        Relies on the percentile sentinel: a just-started replica
        contributes nothing rather than NaN."""
        samples: List[int] = []
        for rep in self.replicas:
            samples.extend(rep.svc.stats.rounds_in_system)
        if not samples:
            return 0.0
        return float(np.percentile(np.asarray(samples), 95))

    def summary(self) -> dict:
        """One flat dict of fleet-level accounting.  Each fleet query
        counts ONCE regardless of hedging; device work appears in
        ``device_computations`` (the sum of per-replica cache misses,
        where a hedge's duplicate computation is visible instead)."""
        recs = list(self._records.values())
        served = sum(rec.status == DONE for rec in recs)
        hits = sum(rec.status == DONE and rec.from_cache
                   for rec in recs)
        return {
            "queries_served": served,
            "fleet_hit_rate": hits / served if served else 0.0,
            "device_computations": sum(
                rep.svc.stats.cache_misses for rep in self.replicas),
            "hedges_launched": self.hedges_launched,
            "hedges_cancelled": self.hedges_cancelled,
            "steps": self._step,
            "p95_rounds": self._aggregate_p95(),
            "per_replica_load": tuple(self._loads),
            "per_replica_served": tuple(
                rep.svc.stats.queries_served
                for rep in self.replicas),
            "w_tail": self.controller.w_tail,
            "hedge_after": self.controller.hedge_after,
        }
