"""CuSP-analog graph partitioner (OEC / IEC / CVC policies).

Produces, for D devices, D edge-disjoint local CSR graphs over the
*global* vertex id space, stacked into one [D, ...] pytree suitable for
``shard_map``, plus a :class:`PartitionMeta` describing the
master/mirror structure the Gluon-analog sync (gluon.py, DESIGN.md
section 6) exchanges over:

* every vertex has exactly one **master** device (contiguous
  ``master_bounds`` ranges — the owner of its canonical label);
* a device **mirrors** every vertex that is an endpoint of one of its
  local edges but is owned elsewhere; the padded per-(device, owner)
  mirror index lists drive the reduce-to-master / broadcast-to-mirrors
  ``ppermute`` pair, replacing the whole-array all-reduce (the
  "communication-heaviest but simplest" starting point).

The partition policy controls *which edges* (and hence which compute)
land on each device, exactly the role OEC/IEC/CVC play in the paper's
Figure 9:

* OEC: vertices -> D contiguous ranges balanced by out-degree; a device
  owns all out-edges of its vertices.
* IEC: same, but balanced by in-degree; a device owns all in-edges of
  its vertex range (edges are assigned by destination).
* CVC: cartesian vertex cut; edge (u,v) -> device grid cell
  (row(u), col(v)) with a near-square device grid.

Master assignment follows the policy's vertex ranges (OEC: the
out-degree bounds, IEC: the in-degree bounds, CVC: the (row, col) cell
of the vertex's own ranges, which is monotone in vertex id), so owned
ranges are always contiguous and the final labels can be assembled by
gathering each vertex from its owner's copy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from .graph import Graph, to_coo


def _ranges_balanced(weights: np.ndarray, parts: int) -> np.ndarray:
    """Contiguous ranges with ~equal total weight. Returns bounds[D+1]."""
    total = int(weights.sum())
    csum = np.concatenate([[0], np.cumsum(weights)])
    targets = (np.arange(1, parts) * total) // parts
    cuts = np.searchsorted(csum, targets, side="left")
    return np.concatenate([[0], cuts, [len(weights)]]).astype(np.int64)


def _stack_local_graphs(edge_lists, num_vertices: int) -> Graph:
    """Build per-device CSR over global vid space, pad E, stack."""
    from .graph import from_edge_list
    locs = [from_edge_list(s, d, num_vertices, weights=w, dedup=False)
            for (s, d, w) in edge_lists]
    emax = max(g.num_edges for g in locs)
    emax = max(emax, 1)
    rows, cols, ws = [], [], []
    for g in locs:
        pad = emax - g.num_edges
        rows.append(np.asarray(g.row_ptr))
        cols.append(np.pad(np.asarray(g.col_idx), (0, pad)))
        ws.append(np.pad(np.asarray(g.edge_w), (0, pad),
                         constant_values=np.int32(1 << 30)))
    return Graph(row_ptr=jnp.asarray(np.stack(rows)),
                 col_idx=jnp.asarray(np.stack(cols)),
                 edge_w=jnp.asarray(np.stack(ws)))


@dataclasses.dataclass(frozen=True)
class PartitionMeta:
    """Master/mirror structure of a partition (DESIGN.md section 6).

    num_devices / num_vertices : partition dimensions
    master_bounds : int64[D+1]  contiguous owned vertex ranges; device d
                    masters vertices [master_bounds[d], master_bounds[d+1])
    owner         : int32[V]    master device of each vertex
    mirror_idx    : int32[D, D, L]  ``mirror_idx[d, o]`` lists the
                    vertices device d mirrors whose master is o (o != d),
                    padded with the sentinel V; L is the max list length
                    over all (d, o) pairs so one ``ppermute`` payload
                    shape serves every ring step
    mirror_counts : int64[D, D] true (un-padded) list lengths
    """
    num_devices: int
    num_vertices: int
    master_bounds: np.ndarray
    owner: np.ndarray
    mirror_idx: np.ndarray
    mirror_counts: np.ndarray

    @property
    def total_mirrors(self) -> int:
        return int(self.mirror_counts.sum())

    @property
    def replication_factor(self) -> float:
        """Average proxies per vertex: 1 master each + all mirrors."""
        return (self.num_vertices + self.total_mirrors) / self.num_vertices


class Partitioned(NamedTuple):
    """``partition()`` result: the stacked local CSRs + sync metadata."""
    graph: Graph
    meta: PartitionMeta


def _build_meta(num_devices: int, num_vertices: int, owner_v: np.ndarray,
                edge_lists) -> PartitionMeta:
    """Mirror lists from per-device edge endpoints and the owner map."""
    bounds = np.searchsorted(owner_v, np.arange(num_devices + 1),
                             side="left").astype(np.int64)
    per_pair: list[list[np.ndarray]] = []
    lmax = 1
    for d in range(num_devices):
        s, t, _ = edge_lists[d]
        ends = np.unique(np.concatenate([s, t])) if len(s) else \
            np.zeros(0, np.int64)
        mirrors = ends[owner_v[ends] != d]
        row = []
        for o in range(num_devices):
            lst = mirrors[owner_v[mirrors] == o]
            lmax = max(lmax, len(lst))
            row.append(lst)
        per_pair.append(row)
    mirror_idx = np.full((num_devices, num_devices, lmax), num_vertices,
                         dtype=np.int32)
    counts = np.zeros((num_devices, num_devices), dtype=np.int64)
    for d in range(num_devices):
        for o in range(num_devices):
            lst = per_pair[d][o]
            mirror_idx[d, o, :len(lst)] = lst
            counts[d, o] = len(lst)
    return PartitionMeta(num_devices=num_devices,
                         num_vertices=num_vertices,
                         master_bounds=bounds,
                         owner=owner_v.astype(np.int32),
                         mirror_idx=mirror_idx,
                         mirror_counts=counts)


def partition(g: Graph, num_devices: int,
              policy: str = "oec") -> Partitioned:
    """Partition ``g``; returns ``(stacked Graph with leading dim D,
    PartitionMeta)``."""
    src, ci, w = to_coo(g)
    n = g.num_vertices
    rp = np.asarray(g.row_ptr).astype(np.int64)
    outdeg = rp[1:] - rp[:-1]

    if policy == "oec":
        bounds = _ranges_balanced(outdeg, num_devices)
        owner = np.searchsorted(bounds, src, side="right") - 1
        owner_v = np.searchsorted(bounds, np.arange(n), side="right") - 1
    elif policy == "iec":
        indeg = np.bincount(ci, minlength=n)
        bounds = _ranges_balanced(indeg, num_devices)
        owner = np.searchsorted(bounds, ci, side="right") - 1
        owner_v = np.searchsorted(bounds, np.arange(n), side="right") - 1
    elif policy == "cvc":
        pr = int(math.sqrt(num_devices))
        while num_devices % pr:
            pr -= 1
        pc = num_devices // pr
        rb = _ranges_balanced(outdeg, pr)
        cb = _ranges_balanced(np.bincount(ci, minlength=n), pc)
        r = np.searchsorted(rb, src, side="right") - 1
        c = np.searchsorted(cb, ci, side="right") - 1
        owner = r * pc + c
        # vertex master = its own (row, col) cell; monotone in vid since
        # both range lookups are, so owned ranges stay contiguous
        rv = np.searchsorted(rb, np.arange(n), side="right") - 1
        cv = np.searchsorted(cb, np.arange(n), side="right") - 1
        owner_v = rv * pc + cv
    else:
        raise ValueError(policy)

    edge_lists = []
    for d in range(num_devices):
        sel = owner == d
        edge_lists.append((src[sel], ci[sel], w[sel]))
    stacked = _stack_local_graphs(edge_lists, n)
    meta = _build_meta(num_devices, n, owner_v.astype(np.int64), edge_lists)
    return Partitioned(stacked, meta)


def partition_stats(stacked: Graph, meta: PartitionMeta | None = None) -> dict:
    rp = np.asarray(stacked.row_ptr)
    local_edges = rp[:, -1]
    st = dict(edges_per_device=local_edges.tolist(),
              imbalance=float(local_edges.max()
                              / max(local_edges.mean(), 1.0)))
    if meta is not None:
        st["replication_factor"] = meta.replication_factor
        st["mirrors_per_device"] = meta.mirror_counts.sum(axis=1).tolist()
    return st
