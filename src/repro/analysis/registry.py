"""Rule registry: id -> ``Rule`` with a check callable.

Rules self-register at import time via :func:`register_rule` (the
``repro.analysis.rules`` package imports every rule module).  Each
rule declares whether it participates in the *relaxed* profile used
for ``tests/`` — test code legitimately syncs results to the host and
stores writable arrays, so only structural rules (static-argnames
drift, jit purity, pragma hygiene) run there.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

# checked by ``__init__.analyze_source``; declared here so rule
# modules and the CLI share one source of truth
RELAXED_PROFILE_DOC = (
    "relaxed profile (tests/): only rules marked `relaxed` run")


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered lint pass."""

    id: str
    """Stable identifier used in findings, pragmas and the baseline."""

    description: str
    """One-line summary shown by ``--help`` / ``--list-rules``."""

    check: Callable
    """``check(ctx: FileContext) -> list[Finding]``."""

    relaxed: bool = False
    """Whether the rule also runs under the relaxed (tests/) profile."""


_RULES: Dict[str, Rule] = {}


def register_rule(rule: Rule) -> Rule:
    """Add ``rule`` to the registry (idempotent per id)."""
    existing = _RULES.get(rule.id)
    if existing is not None and existing is not rule:
        raise ValueError(f"duplicate rule id: {rule.id!r}")
    _RULES[rule.id] = rule
    return rule


def all_rules() -> List[Rule]:
    """Every registered rule, sorted by id."""
    _ensure_loaded()
    return [_RULES[k] for k in sorted(_RULES)]


def get_rules(relaxed: bool = False) -> List[Rule]:
    """Rules for a profile: all of them, or only the relaxed subset."""
    rules = all_rules()
    if relaxed:
        rules = [r for r in rules if r.relaxed]
    return rules


def rule_ids() -> List[str]:
    """Sorted ids of every registered rule."""
    return [r.id for r in all_rules()]


def _ensure_loaded() -> None:
    # rule modules register on import; tolerate being imported first
    from . import rules  # noqa: F401
