"""``static-argnames``: every static name must be a real parameter.

``jax.jit(fn, static_argnames=("cfg",))`` with a typo'd name raises
nothing — JAX just ignores it, the argument stays traced, and every
distinct value recompiles.  This pass resolves each jit application
(decorator, ``partial(jax.jit, ...)``-application, or direct call
form) to its target def and checks the literal ``static_argnames``
against the def's parameter list.  Unresolvable targets (imported
functions, non-literal name tuples) are skipped, not guessed.
"""
from __future__ import annotations

from typing import List

from .. import astutil
from ..findings import Finding
from ..registry import Rule, register_rule

RULE_ID = "static-argnames"


def check(ctx) -> List[Finding]:
    """Run the static-argnames drift pass over one file."""
    out: List[Finding] = []
    for b in ctx.jit_bindings:
        if b.func is None or b.static_node is None:
            continue
        if b.static_names is None:
            out.append(ctx.finding(
                b.static_node, RULE_ID,
                f"static_argnames for `{b.func_name}` is not a "
                f"string/tuple literal — the drift check cannot "
                f"verify it"))
            continue
        params = astutil.param_names(b.func)
        missing = [n for n in b.static_names if n not in params]
        for name in missing:
            out.append(ctx.finding(
                b.static_node, RULE_ID,
                f"static_argnames {name!r} is not a parameter of "
                f"`{b.func_name}` (params: {', '.join(params)}) — "
                f"the argument silently stays traced"))
    return out


register_rule(Rule(
    id=RULE_ID,
    description="names in static_argnames= must match a parameter of "
                "the jitted function (a typo silently recompiles)",
    check=check,
    relaxed=True,
))
