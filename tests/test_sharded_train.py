"""Sharded execution correctness: the SAME sharding rules the dry-run
uses, executed for real on a small forced-device-count mesh, must match
single-device training bit-for-bit-ish.

Runs in a subprocess so device count never leaks.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.train.steps import make_train_step, init_train_state
from repro.optim import OptConfig
from repro.launch import sharding as SH

assert len(jax.devices()) == 4
for arch in ["llama3-8b", "deepseek-moe-16b", "mamba2-2.7b"]:
    cfg = get_smoke_config(arch)
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}

    ref_step = jax.jit(make_train_step(cfg, OptConfig()))
    rp, ro, rm = ref_step(params, opt, batch)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    pspec = SH.param_specs(jax.eval_shape(lambda: params))
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    psh = named(pspec)
    osh = named(SH.opt_specs(pspec))
    bsh = named(SH.batch_specs(False, cfg.num_codebooks))
    shard_fn = SH.make_shard_fn(mesh, False)
    with mesh:
        sharded_step = jax.jit(
            make_train_step(cfg, OptConfig(), shard_fn),
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, named({"loss": P(), "ce": P(),
                                            "grad_norm": P()})))
        sp, so, sm = sharded_step(params, opt, batch)
    assert np.allclose(float(rm["loss"]), float(sm["loss"]),
                       rtol=2e-3, atol=2e-3), (
        arch, float(rm["loss"]), float(sm["loss"]))
    # spot-check a parameter leaf after the update
    rl = jax.tree.leaves(rp)[0]
    sl = jax.tree.leaves(sp)[0]
    assert np.allclose(np.asarray(rl), np.asarray(sl), rtol=1e-2,
                       atol=1e-3), arch
    print(arch, "sharded==single ok", float(rm["loss"]))
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_OK" in out.stdout
