"""Static analysis for the repo's structural invariants.

A stdlib-``ast`` lint framework (no dependencies, never imports jax)
that turns the codebase's runtime disciplines — fused rounds pay zero
host syncs, jitted functions never retrace on data, served arrays are
frozen before they are shared, executor scatters are order-free —
into CI-enforced program structure.  See DESIGN.md section 12.

Usage::

    PYTHONPATH=src python -m repro.analysis --check src/ benchmarks/
    PYTHONPATH=src python -m repro.analysis --check --relaxed tests/

Findings print as ``file:line rule-id message``.  Suppress a single
line with ``# repro: allow[<rule>] -- <justification>``; grandfather
legacy findings in ``analysis-baseline.txt`` (never for
``src/repro/core`` or ``src/repro/serve``).
"""
from .baseline import (PROTECTED_PREFIXES, apply_baseline,
                       load_baseline, protected_violations,
                       render_baseline)
from .findings import Finding
from .linter import (FileContext, Session, analyze_paths,
                     analyze_source, iter_python_files)
from .pragmas import parse_pragmas
from .registry import Rule, all_rules, get_rules, register_rule, rule_ids

__all__ = [
    "Finding", "Rule", "Session", "FileContext",
    "analyze_source", "analyze_paths", "iter_python_files",
    "all_rules", "get_rules", "register_rule", "rule_ids",
    "parse_pragmas",
    "load_baseline", "apply_baseline", "render_baseline",
    "protected_violations", "PROTECTED_PREFIXES",
]
