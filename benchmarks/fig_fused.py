"""Device-resident planning: fused vs host round loops (DESIGN.md
section 11).

``mode="host"`` drivers run the inspector on the host: every round
pays one blocking device->host transfer to read the fused counts
before the next round can launch.  ``mode="fused"`` moves the whole
plan on device — bin selection, the huge-bin LB trigger, and the
Beamer push/pull rule run as traced ``lax.cond``s inside ONE
``lax.while_loop``, so a full traversal costs zero per-round host
syncs.  This harness times both modes per (app x graph) and reports
the round counts plus the ``host_transfers`` counter each traversal
actually performed.

Rows: ``fused_<app>_<graph>_<mode>,us_per_run,rounds=N ht=K``.

Run directly (also wired as the ``fused`` selector of benchmarks.run):

    PYTHONPATH=src python -m benchmarks.fig_fused          # sweep
    PYTHONPATH=src python -m benchmarks.fig_fused --smoke  # CI

``--smoke`` shrinks the input and gates on STRUCTURAL invariants only
(never wall clock — fused wins by removing sync latency, which CI
timers cannot measure reliably):

1. parity — fused labels are bitwise equal to host labels and the
   round counts match, per app x graph;
2. zero-sync — the fused traversal reports ``host_transfers == 0``
   (the loop never blocked on a device value), both on the
   :class:`repro.core.apps.AppResult` and on every per-round stat
   materialized from the device-accumulated buffers, while the host
   traversal reports at least one transfer per round;
3. trace — the fused run's recorded per-round direction equals
   :func:`repro.core.balancer.resolve_direction` replayed on the host
   over the device-recorded per-round counts (frontier size and
   out-edge total), i.e. the on-device ``lax.cond`` made exactly the
   decisions the host threshold rule would have.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import graph as G
from repro.core.apps import bfs, cc, pagerank, sssp
from repro.core.balancer import BalancerConfig, resolve_direction

from .common import timed, emit

MODES = ["host", "fused"]


def _inputs(smoke: bool) -> dict:
    if smoke:
        return {"rmat": G.rmat(9, 8, seed=1),
                "road": G.road_grid(16, seed=1)}
    return {"rmat": G.rmat(12, 16, seed=1),
            "road": G.road_grid(64, seed=1)}


def _gate_traversal(tag: str, host, fused, cfg, v: int, e: int) -> int:
    """The three structural gates for one app x graph cell; returns
    the number of failures (0 = all invariants hold)."""
    failures = 0
    # 1. parity: fused is an execution strategy, not an approximation
    if not np.array_equal(np.asarray(fused.labels),
                          np.asarray(host.labels)):
        print(f"FAIL: {tag}: fused labels != host labels",
              file=sys.stderr)
        failures += 1
    if fused.rounds != host.rounds:
        print(f"FAIL: {tag}: fused ran {fused.rounds} rounds, host "
              f"ran {host.rounds}", file=sys.stderr)
        failures += 1
    # 2. zero-sync: the while_loop never blocked on a device value
    if fused.host_transfers != 0:
        print(f"FAIL: {tag}: fused traversal performed "
              f"{fused.host_transfers} host transfers (want 0)",
              file=sys.stderr)
        failures += 1
    if any(st.host_transfers != 0 for st in fused.stats):
        print(f"FAIL: {tag}: a fused per-round stat claims a host "
              f"transfer", file=sys.stderr)
        failures += 1
    if host.host_transfers < host.rounds:
        print(f"FAIL: {tag}: host traversal reports "
              f"{host.host_transfers} transfers for {host.rounds} "
              f"rounds — instrumentation broke", file=sys.stderr)
        failures += 1
    # 3. trace: replay the host threshold rule over the counts the
    #    device accumulated; the on-device lax.cond must agree
    for i, st in enumerate(fused.stats):
        want = resolve_direction(cfg, st.frontier_size,
                                 st.frontier_edges, v, e)
        if st.direction != want:
            print(f"FAIL: {tag} round {i}: device picked "
                  f"{st.direction}, threshold rule over the recorded "
                  f"counts says {want}", file=sys.stderr)
            failures += 1
    return failures


def run(smoke: bool = False) -> int:
    cfg = BalancerConfig(strategy="alb", threshold=64,
                         direction="adaptive")
    apps = {"bfs": bfs, "sssp": sssp}
    failures = 0
    for gname, g in _inputs(smoke).items():
        src = G.highest_out_degree_vertex(g)
        v, e = g.num_vertices, g.num_edges
        for app_name, driver in apps.items():
            results = {}
            for mode in MODES:
                out = driver(g, src, cfg, direction="adaptive",
                             collect_stats=True, mode=mode)
                secs = timed(lambda m=mode: driver(g, src, cfg,
                                                   direction="adaptive",
                                                   mode=m))
                emit(f"fused_{app_name}_{gname}_{mode}", secs,
                     f"rounds={out.rounds} ht={out.host_transfers}")
                results[mode] = out
            failures += _gate_traversal(f"{app_name}/{gname}",
                                        results["host"],
                                        results["fused"], cfg, v, e)
        # vertex programs without a source: parity + zero-sync only
        # (cc runs on the symmetrized graph; pagerank is push-only)
        if not smoke or gname == "road":
            sg = G.symmetrized(g)
            ch = cc(sg, cfg, collect_stats=True)
            cf = cc(sg, cfg, collect_stats=True, mode="fused")
            emit(f"fused_cc_{gname}_host", 0.0,
                 f"rounds={ch.rounds} ht={ch.host_transfers}")
            emit(f"fused_cc_{gname}_fused", 0.0,
                 f"rounds={cf.rounds} ht={cf.host_transfers}")
            failures += _gate_traversal(f"cc/{gname}", ch, cf, cfg,
                                        sg.num_vertices, sg.num_edges)
            pcfg = BalancerConfig(strategy="alb", threshold=64)
            ph = pagerank(g, cfg=pcfg)
            pf = pagerank(g, cfg=pcfg, mode="fused")
            if not np.array_equal(np.asarray(pf.labels),
                                  np.asarray(ph.labels)):
                print(f"FAIL: pagerank/{gname}: fused ranks != host "
                      f"ranks", file=sys.stderr)
                failures += 1
            if pf.host_transfers != 0:
                print(f"FAIL: pagerank/{gname}: fused performed "
                      f"{pf.host_transfers} host transfers",
                      file=sys.stderr)
                failures += 1
    return failures


def main() -> int:
    smoke = "--smoke" in sys.argv[1:]
    failures = run(smoke=smoke)
    if failures:
        return 1
    if smoke:
        print("smoke OK: fused parity + zero host syncs + direction "
              "trace replay")
    return 0


if __name__ == "__main__":
    sys.exit(main())
