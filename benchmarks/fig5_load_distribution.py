"""Fig 1/5 analogue: per-tile ("thread block") edge-load distribution
with and without ALB, round by round.

Both execution modes are measured: ``host`` (the host-driven round used
for single-device wall clock) and ``spmd`` (the fully-jit round used
inside the distributed runtime, whose jit-safe RoundStatsDev
instrumentation this harness surfaces — DESIGN.md section 3)."""
from __future__ import annotations

import numpy as np

from repro.core.balancer import BalancerConfig
from repro.core import graph as G
from repro.core.apps import sssp

from .common import bench_graphs, emit

MODES = ["host", "spmd"]


def imbalance(loads: np.ndarray) -> float:
    mean = max(loads.mean(), 1.0)
    return float(loads.max() / mean)


def run(scale: int = 13):
    g = bench_graphs(scale)["rmat"]
    src = G.highest_out_degree_vertex(g)
    out = {}
    for strat in ["twc", "alb"]:
        for mode in MODES:
            cfg = BalancerConfig(strategy=strat, threshold=1024)
            res = sssp(g, src, cfg, collect_stats=True, mode=mode)
            for rnd, st in enumerate(res.stats[:4]):
                total = st.tile_loads_twc + st.tile_loads_lb
                imb = imbalance(total)
                out[(strat, mode, rnd)] = imb
                emit(f"fig5/{strat}/{mode}/round{rnd}", res.seconds,
                     f"imbalance={imb:.1f} edges_twc={st.edges_twc} "
                     f"edges_lb={st.edges_lb} lb_fired={st.lb_invoked}")
    return out


if __name__ == "__main__":
    run()
