"""Pluggable wire codecs for the distributed sync payload path.

The mirror substrate's scaling bottleneck is inter-device
communication volume (the quantity ``RoundStats.bytes_synced``
tracks); this module separates *what* a sync round ships (the dirty
boundary payload gluon.py assembles) from *how* it is packed on the
wire, following the composable work-definition/scheduling split of
Osama et al. (PAPERS.md): codecs compose with every app, sync
substrate, and execution mode instead of being hand-welded into one
exchange.

A codec is a :class:`WireCodec`: ``encode`` / ``decode`` transform the
per-ring-step payload slab (jit-safe, fixed output shapes — nothing
recompiles when the dirty set changes), and the byte accountants
(``step_wire_bytes`` / ``allreduce_wire_bytes``) report what the
encoded representation would occupy on a real wire, as jit ``int32``
scalars that ride the round's existing stats.  The **logical** volume
(``bytes_synced``: one index word plus the ``[B]`` label vector per
exchanged vertex) is codec-independent; ``bytes_wire`` is the
post-encode volume, and ``bytes_wire / bytes_synced`` is the
compression ratio fig6 records.

Four codecs are registered:

* ``identity`` — bitwise today's behavior; ``bytes_wire ==
  bytes_synced``.  The default, and the parity reference.
* ``delta`` — ship label deltas against the previous round's synced
  values.  The reference state is the round-entry label array the
  shard_map loop already carries (host loop and fused
  ``lax.while_loop`` alike): after every broadcast a master's copy and
  its mirrors' copies agree for every mirror-list vertex, so both ends
  of a ring step reconstruct the same reference and integer deltas
  decode exactly (two's-complement wraparound makes ``(a - b) + b``
  an identity).  Unchanged entries ship nothing; changed entries ship
  a frame-of-reference offset (1/2/4 bytes against the per-query
  minimum of the step's changed values) behind a 2-bit-per-entry code
  stream.  Float payloads (pagerank) ship raw — float subtraction
  does not round-trip bitwise — and compress by suppression only.
* ``quantize`` — narrow dtypes where the app's combine tolerates it:
  the operator must declare its safe narrowings
  (:attr:`repro.core.operators.Operator.wire_narrow`); an app whose
  operator declares none **raises at config time**.  min-combine
  payloads map through a saturating sentinel (the narrow dtype's max
  encodes "unreached"/neutral, exact while true labels stay below
  it); add-combine payloads wrap two's-complement into the narrow
  word and sign-extend back (exact while magnitudes fit).  The ring
  genuinely ships the narrow array.  BFS hop counts and k-core
  degree deltas fit ``uint16``; bounded-depth traversals fit
  ``int8`` (``wire="quantize:int8"`` selects a non-default declared
  narrowing).
* ``bitmap`` — pack the dirty mask 8 vertices/byte for the index side
  of the exchange: a ring step whose live set is dense ships an
  ``ceil(L/8)``-byte bitmap over its (static) mirror-list slots
  instead of one 4-byte index word per live vertex; sparse steps keep
  the index list (the transport envelope's length field disambiguates
  the two layouts, so the hybrid costs no tag byte).  Payload bytes
  are unchanged.

The block-absmax quantization idiom shared with the gradient
compressor lives here too (:func:`pad_to_block` /
:func:`block_absmax_scale`); ``repro.optim.grad_compress`` imports it
rather than keeping a private copy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .graph import INF
from .operators import Operator

#: bytes of the per-vertex index word the uncompressed exchange ships
#: alongside each dirty vertex's payload (int32 vertex ids)
INDEX_BYTES = 4

#: block length of the shared block-absmax quantization idiom (also
#: used by the optimizer-side gradient compressor)
BLOCK = 256


# ---------------------------------------------------------------------------
# shared quantize helpers (the block-absmax idiom; grad_compress
# imports these instead of keeping a private copy)
# ---------------------------------------------------------------------------

def pad_to_block(x: jax.Array, block: int = BLOCK):
    """Flatten ``x`` and pad to a whole number of ``block``-wide rows.

    Returns ``(blocks[N, block], npad)`` — the shared first step of
    every block-scaled quantization scheme in the tree."""
    n = x.size
    npad = -(-n // block) * block - n
    flat = x.reshape(-1)
    if npad:
        flat = jnp.pad(flat, (0, npad))
    return flat.reshape(-1, block), npad


def block_absmax_scale(blocks: jax.Array, qmax: float = 127.0,
                       eps: float = 1e-12) -> jax.Array:
    """Per-block symmetric absmax scale (``[N, 1]``, floored at
    ``eps``): the quantization step that maps each block of values
    onto ``[-qmax, qmax]``."""
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / qmax
    return jnp.maximum(scale, eps)


# ---------------------------------------------------------------------------
# codec protocol + registry
# ---------------------------------------------------------------------------

def _is_float(dtype) -> bool:
    return jnp.issubdtype(jnp.dtype(dtype), jnp.floating)


def _narrow_info(name: str):
    """(jnp dtype, itemsize, min-combine sentinel) of a declared
    narrowing."""
    if name == "uint16":
        return jnp.uint16, 2, (1 << 16) - 1
    if name == "int8":
        return jnp.int8, 1, (1 << 7) - 1
    if name == "uint8":
        return jnp.uint8, 1, (1 << 8) - 1
    if name == "int16":
        return jnp.int16, 2, (1 << 15) - 1
    raise ValueError(f"unsupported wire narrowing dtype {name!r}")


#: dtype names a quantize codec may ship — the set the
#: ``dtype-narrowing`` lint pass cross-checks operator declarations
#: against
NARROW_DTYPES = frozenset({"int8", "uint8", "int16", "uint16"})


@dataclasses.dataclass(frozen=True)
class WireCodec:
    """One wire packing of the sync payload path (see module
    docstring).  Frozen and stateless: per-round reference state (the
    ``delta`` codec's previous-synced labels) is the round-entry label
    array the caller's loop already carries, passed in per call.

    All methods are jit-safe with fixed output shapes, so swapping
    codecs never recompiles the round beyond the one trace per
    (cfg, op) jit key the ``wire`` config field already implies.
    """

    #: registry name ("identity" | "delta" | "quantize" | "bitmap")
    name: str

    #: narrow dtype name shipped by the quantize codec (None elsewhere)
    narrow: Optional[str] = None

    # -- config-time validation ------------------------------------------

    def validate(self, op: Operator, dtype) -> None:
        """Raise (at config time, before any round runs) when this
        codec cannot carry ``op``'s payloads exactly.

        Only ``quantize`` constrains the pairing: the operator must
        declare the requested narrowing in
        :attr:`~repro.core.operators.Operator.wire_narrow`."""
        if self.name != "quantize":
            return
        if not op.wire_narrow:
            raise ValueError(
                f"wire codec 'quantize' needs an operator that "
                f"declares a safe narrowing; {op.name} declares none "
                f"(its combine does not tolerate narrow payloads — "
                f"DESIGN.md section 14)")
        if self.narrow not in op.wire_narrow:
            raise ValueError(
                f"operator {op.name} declares safe narrowings "
                f"{op.wire_narrow}; requested {self.narrow!r} is not "
                f"among them")
        if _is_float(dtype):
            raise ValueError(
                f"wire codec 'quantize' is exact only for integer "
                f"payloads; {op.name} ships {jnp.dtype(dtype).name}")

    # -- payload transform (per ring step) -------------------------------

    def encode(self, payload: jax.Array, prev: jax.Array,
               op: Operator) -> jax.Array:
        """Encode one ring step's ``[B, L]`` payload slab.

        ``prev`` is the ``[B, L]`` previous-synced reference gathered
        at the same slots — both ends of the step hold an identical
        copy for every real (non-padding) slot, which is what makes
        ``delta`` decodable.  The output shape is fixed (``[B, L]``,
        possibly narrower dtype), so the ``lax.ppermute`` that ships
        it never changes signature."""
        if self.name == "delta" and not _is_float(payload.dtype):
            return payload - prev
        if self.name == "quantize":
            ndt, _, sent = _narrow_info(self.narrow)
            if op.combine == "min":
                return jnp.minimum(payload, sent).astype(ndt)
            return payload.astype(ndt)  # add: two's-complement wrap
        return payload

    def decode(self, wire: jax.Array, prev: jax.Array,
               op: Operator, dtype, signed: bool = True) -> jax.Array:
        """Exact inverse of :meth:`encode` given the receiver's copy
        of the same ``prev`` reference; returns the logical payload in
        the label dtype.

        ``signed`` disambiguates the add-combine quantize widening,
        where the narrow word alone cannot tell ``-1`` from ``2^16-1``:
        the reduce ring ships two's-complement-wrapped deltas (may be
        negative — sign-extend, exact while ``|value| < 2^(bits-1)``),
        while the broadcast ring ships full labels (non-negative by
        construction — ``signed=False`` zero-extends unsigned narrow
        words, exact while ``value < 2^bits``; without it kcore's
        remaining degrees in ``[2^15, 2^16)`` would decode negative).
        Signed narrow dtypes and every other codec ignore the flag."""
        if self.name == "delta" and not _is_float(dtype):
            return prev + wire
        if self.name == "quantize":
            _, _, sent = _narrow_info(self.narrow)
            if op.combine == "min":
                wide = wire.astype(dtype)
                return jnp.where(wire == jnp.asarray(sent, wire.dtype),
                                 jnp.asarray(INF, dtype), wide)
            # add: widen the wrapped narrow word back to the label
            # dtype — through the same-width signed dtype when the
            # payload may be negative, directly (zero-extending
            # unsigned words) when it is a non-negative label
            if signed and jnp.issubdtype(jnp.dtype(self.narrow),
                                         jnp.unsignedinteger):
                bits = jnp.dtype(self.narrow).itemsize * 8
                return wire.astype(jnp.dtype(f"int{bits}")).astype(dtype)
            return wire.astype(dtype)
        return wire

    # -- wire accounting (jit int32 scalars) -----------------------------

    def step_wire_bytes(self, payload: jax.Array, prev: jax.Array,
                        live: jax.Array, op: Operator) -> jax.Array:
        """Post-encode bytes of one mirror ring step.

        ``payload``/``prev``: ``[B, L]`` slabs; ``live``: ``[L]``
        which slots actually carry traffic (padding and clean slots
        ship nothing under every codec).  The uncompressed baseline
        for the same step is ``n_live * (INDEX_BYTES + B * itemsize)``
        (:func:`step_logical_bytes`)."""
        b = payload.shape[0]
        isz = payload.dtype.itemsize
        n_live = jnp.sum(live.astype(jnp.int32))
        if self.name == "identity":
            return n_live * jnp.int32(INDEX_BYTES + b * isz)
        if self.name == "quantize":
            _, nisz, _ = _narrow_info(self.narrow)
            return n_live * jnp.int32(INDEX_BYTES + b * nisz)
        if self.name == "bitmap":
            # hybrid index side: bitmap over the step's L static slots
            # when denser than the raw index list (the transport
            # envelope's length field tells the layouts apart)
            lcap = live.shape[0]
            idx = jnp.minimum(n_live * INDEX_BYTES,
                              jnp.int32(-(-lcap // 8)))
            idx = jnp.where(n_live > 0, idx, 0)
            return idx + n_live * jnp.int32(b * isz)
        # delta: indices + 2-bit entry codes + per-entry offset bytes
        changed = live[None, :] & (payload != prev)
        n_changed_q = jnp.sum(changed.astype(jnp.int32), axis=1)  # [B]
        if _is_float(payload.dtype):
            # floats ship raw behind a 1-bit change mask: suppression
            # is the only (exact) compression available
            mask_bytes = n_live * jnp.int32(-(-b // 8))
            return (n_live * jnp.int32(INDEX_BYTES) + mask_bytes
                    + jnp.sum(n_changed_q) * jnp.int32(isz))
        # frame of reference: per-query base = min changed value; each
        # changed entry ships its (non-negative) offset in 1/2/4 bytes.
        # int32 arithmetic is exact here: min-combine labels live in
        # [0, INF=2^30] and add-combine payloads are small deltas, so
        # the changed-value spread never wraps.
        wide = payload.astype(jnp.int32)
        big = jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)
        base = jnp.min(jnp.where(changed, wide, big), axis=1,
                       keepdims=True)                         # [B, 1]
        off = jnp.where(changed, wide - base, 0)
        entry = jnp.where(off < (1 << 8), 1,
                          jnp.where(off < (1 << 16), 2, isz))
        entry_bytes = jnp.sum(
            jnp.where(changed, entry, 0).astype(jnp.int32))
        base_bytes = jnp.sum(
            (n_changed_q > 0).astype(jnp.int32)) * jnp.int32(isz)
        code_bytes = n_live * jnp.int32(-(-(2 * b) // 8))
        return (n_live * jnp.int32(INDEX_BYTES) + code_bytes
                + base_bytes + entry_bytes)

    def allreduce_wire_bytes(self, new: jax.Array, prev: jax.Array
                             ) -> jax.Array:
        """Post-encode per-device bytes of one replicated all-reduce
        round over ``[B, V]`` labels (``prev``: the round-entry
        labels; for delta-sync operators the payload is already a
        delta against zeros and ``prev`` is the zero array).

        The all-reduce is dense — there is no index side — so
        ``bitmap`` degenerates to ``identity``; ``delta`` models a
        sparse all-reduce (changed entries behind a 1-bit mask) and
        ``quantize`` a narrow-word one."""
        isz = new.dtype.itemsize
        if self.name == "quantize":
            _, nisz, _ = _narrow_info(self.narrow)
            return jnp.int32(new.size * nisz)
        if self.name == "delta":
            changed = jnp.sum((new != prev).astype(jnp.int32))
            return jnp.int32(-(-new.size // 8)) + changed * jnp.int32(isz)
        return jnp.int32(new.size * isz)


def step_logical_bytes(live: jax.Array, batch: int, itemsize: int
                       ) -> jax.Array:
    """Codec-independent **logical** bytes of one ring step: every
    live vertex ships its int32 index word plus its ``[B]`` label
    vector.  This is what ``bytes_synced`` accumulates (the index side
    included — see tests/test_mirror_sync.py's accounting regression)
    and the denominator of the compression ratio."""
    n_live = jnp.sum(live.astype(jnp.int32))
    return n_live * jnp.int32(INDEX_BYTES + batch * itemsize)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

IDENTITY = WireCodec("identity")
DELTA = WireCodec("delta")
BITMAP = WireCodec("bitmap")

_CODECS = {"identity": IDENTITY, "delta": DELTA, "bitmap": BITMAP}
_QUANTIZE_CACHE: dict = {}

WIRE_NAMES = ("identity", "delta", "quantize", "bitmap")


def get_codec(wire: str, op: Optional[Operator] = None,
              dtype=None) -> WireCodec:
    """Resolve a :class:`BalancerConfig.wire` spec to a codec.

    ``"quantize"`` picks the operator's first declared narrowing;
    ``"quantize:<dtype>"`` requests a specific one (it must still be
    declared).  When ``op`` (and optionally ``dtype``) are given the
    pairing is validated immediately — the config-time raise the
    acceptance gate demands; codec lookups without an operator (e.g.
    for config validation alone) skip it."""
    if wire in _CODECS:
        codec = _CODECS[wire]
    else:
        base, _, req = wire.partition(":")
        if base != "quantize":
            raise ValueError(
                f"unknown wire codec {wire!r} (expected one of "
                f"{WIRE_NAMES} or 'quantize:<dtype>')")
        if req and req not in NARROW_DTYPES:
            raise ValueError(
                f"wire codec {wire!r}: {req!r} is not a supported "
                f"narrow dtype ({sorted(NARROW_DTYPES)})")
        narrow = req or None
        if narrow is None:
            if op is None:
                # config syntax is valid; the narrowing is resolved
                # (and validated) once the operator is known
                return WireCodec("quantize", narrow=None)
            if not op.wire_narrow:
                raise ValueError(
                    f"wire codec 'quantize' needs an operator that "
                    f"declares a safe narrowing; {op.name} declares "
                    f"none (DESIGN.md section 14)")
            narrow = op.wire_narrow[0]
        key = narrow
        if key not in _QUANTIZE_CACHE:
            _narrow_info(narrow)      # reject unsupported names early
            _QUANTIZE_CACHE[key] = WireCodec("quantize", narrow=narrow)
        codec = _QUANTIZE_CACHE[key]
    if op is not None:
        codec.validate(op, dtype if dtype is not None else jnp.int32)
    return codec


def validate_wire(wire: str) -> None:
    """Config-syntax check for :class:`BalancerConfig.__post_init__`:
    the spec must name a registered codec (operator pairing is checked
    later, when the driver knows its operator)."""
    get_codec(wire)
