"""Pallas TPU kernel for the merge-path executor (third backend).

Merge-path load balancing (Merrill & Garland's SpMV scheme, the
segmented-scan form of Gunrock-LB) removes the inspector entirely:
the frontier's whole edge range ``[0, total)`` is cut into equal-work
tiles of ``tile_edges`` edge ids each, and every tile locates its own
slice of the frontier by *co-ranked* binary search over the exclusive
degree prefix sum ``start_e`` — a diagonal search on the (vertices,
edges) merge matrix.  No degree bins, no huge-bin detection, no
per-round planning of any kind: the only data-dependent quantity is
``total``, a device scalar, which is why the executor drops into the
fused device-resident traversal loop (DESIGN.md section 11) with zero
host involvement.

Per grid step (one equal-work tile):

1. two *scalar* co-rank searches bound the tile's source-slot window:
   ``lo_j = rank(first edge id)`` and ``hi_j = rank(last edge id)`` —
   the tile's diagonal intersections with the merge path;
2. each lane then binary-searches its own edge id **restricted to**
   ``[lo_j, hi_j + 1)`` — the window is typically a handful of slots
   (a tile of E/T edges crosses few vertices unless degrees are tiny),
   so the per-lane search touches a narrow, VPU-uniform span of
   ``start_e`` instead of the whole array (contrast ``edge_lb.py``,
   whose every lane searches the full ``[0, H)`` range);
3. the tile emits (graph_edge, slot) pairs; the irregular gathers and
   the scatter-combine stay in the XLA epilogue
   (``ops.merge_path_apply*``), exactly like the other two backends.

The per-lane loop keeps a fixed ``ceil(log2(H))`` trip count (runs of
zero-degree slots can widen a window arbitrarily, so the bound cannot
be lowered statically), but every iteration past the window's true
depth is a no-op on converged lanes — the narrowing is where the
merge-path locality comes from, the equal-work tiling is where the
balance comes from.

Enumeration contract: ids are dealt contiguously (tile t owns
``[t * tile_edges, (t+1) * tile_edges)``), so per-tile edge loads
differ by at most one partial tail tile — the ``distribution`` knob of
the other backends does not apply.  Ids at or past ``total`` are
masked before any memory traffic.  Validated in interpret mode against
a numpy searchsorted oracle (tests/test_fused.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(start_ref, row_ref, total_ref, ge_ref, slot_ref, msk_ref,
            *, tile_r: int, h: int):
    i = pl.program_id(0)
    tile = tile_r * 128
    lin = (jax.lax.broadcasted_iota(jnp.int32, (tile_r, 128), 0) * 128
           + jax.lax.broadcasted_iota(jnp.int32, (tile_r, 128), 1))
    eid = i * tile + lin
    total = total_ref[0, 0]
    emask = eid < total
    eid_c = jnp.where(emask, eid, 0)

    start_e = start_ref[0, :]                      # [H] whole, in VMEM
    row_start = row_ref[0, :]
    steps = max(1, (h - 1).bit_length())

    # ---- co-rank: scalar diagonal searches bound the slot window ----
    def co_rank(x):
        def body(_, carry):
            lo, hi = carry
            mid = (lo + hi) // 2
            go_right = jnp.take(start_e, mid) <= x
            return (jnp.where(go_right, mid + 1, lo),
                    jnp.where(go_right, hi, mid))
        lo, _ = jax.lax.fori_loop(
            0, steps, body, (jnp.int32(0), jnp.int32(h)))
        return jnp.clip(lo - 1, 0, h - 1)

    t_lo = i * tile
    t_hi = jnp.clip(total - 1, t_lo, t_lo + tile - 1)
    lo_j = co_rank(jnp.int32(t_lo))                # first slot touched
    hi_j = co_rank(t_hi)                           # last slot touched

    # ---- per-lane search, restricted to [lo_j, hi_j + 1) ------------
    lo = jnp.full_like(eid_c, lo_j)
    hi = jnp.full_like(eid_c, hi_j + 1)

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        go_right = jnp.take(start_e, mid) <= eid_c
        return (jnp.where(go_right, mid + 1, lo),
                jnp.where(go_right, hi, mid))
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    j = jnp.clip(lo - 1, 0, h - 1)

    ge_ref[...] = jnp.where(emask,
                            jnp.take(row_start, j)
                            + (eid_c - jnp.take(start_e, j)), 0)
    slot_ref[...] = j
    msk_ref[...] = emask.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("ecap", "tile_edges", "interpret"))
def merge_path_map(start_e: jax.Array, row_start: jax.Array,
                   total_edges: jax.Array, ecap: int, *,
                   tile_edges: int = 2048, interpret: bool = True):
    """Run the merge-path mapping kernel over ``ecap`` edge ids.

    ``start_e`` / ``row_start`` are the ``[H]`` exclusive degree prefix
    sum and CSR row starts of the frontier members; ``total_edges`` is
    the live edge count (device scalar, ids past it are masked).
    Returns ``(graph_e, slot_j, mask)`` flat arrays of length
    ``ceil(ecap / tile_edges) * tile_edges`` — each kernel grid step is
    one equal-work tile of ``tile_edges`` consecutive edge ids.
    """
    h = start_e.shape[0]
    tile_r = tile_edges // 128
    assert tile_edges % 128 == 0
    grid = max(1, -(-ecap // tile_edges))
    n_rows = grid * tile_r

    out_shape = [
        jax.ShapeDtypeStruct((n_rows, 128), jnp.int32),   # graph_e
        jax.ShapeDtypeStruct((n_rows, 128), jnp.int32),   # slot j
        jax.ShapeDtypeStruct((n_rows, 128), jnp.int32),   # mask
    ]
    kern = functools.partial(_kernel, tile_r=tile_r, h=h)
    full = pl.BlockSpec((1, h), lambda i: (0, 0))
    outs = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[full, full, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((tile_r, 128), lambda i: (i, 0))] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(start_e[None, :], row_start[None, :],
      jnp.asarray(total_edges, jnp.int32).reshape(1, 1))
    ge, j, msk = (o.reshape(-1) for o in outs)
    return ge, j, msk.astype(bool)
