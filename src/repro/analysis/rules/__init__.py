"""The lint passes.  Importing this package registers every rule.

Rule ids (see each module for the full story):

* ``host-sync`` — blocking device->host transfers in core/serve must
  be registered ``_note_host_transfer`` sites or pragma'd.
* ``jit-purity`` — no Python control flow on tracers, print, global
  mutation, or wall-clock/RNG inside jitted/pallas functions.
* ``static-argnames`` — static_argnames entries must name real
  parameters of the jitted function.
* ``publish-freeze`` — arrays published by the serve layer must pass
  through the ``freeze()`` helper.
* ``scatter-determinism`` — executor ``.at[...]`` scatters must use
  a combine registered commutative-associative in operators.py.
* ``dtype-narrowing`` — narrow ``.astype`` in core/ must be a
  ``wire_narrow``-declared safe narrowing from operators.py.
* ``bad-pragma`` — suppression pragmas must be well-formed.
"""
from . import dtype_narrowing  # noqa: F401
from . import host_sync  # noqa: F401
from . import jit_purity  # noqa: F401
from . import pragma_hygiene  # noqa: F401
from . import publish_freeze  # noqa: F401
from . import scatter_determinism  # noqa: F401
from . import static_args  # noqa: F401
