"""Pallas TPU flash attention (forward) — the prefill fast path.

Grid: (batch*kv_heads, q_blocks); each grid step streams KV blocks of
``block_k`` rows through VMEM with the online-softmax recurrence.  The
q/k/v tiles are explicit BlockSpecs (MXU-aligned: block_q × head_dim
and block_k × head_dim, both 128-multiples for full-size heads).

This kernel is the TPU-native replacement for the pure-JAX
``chunked_attention`` scan (ref.py oracle = plain softmax attention);
causal masking skips fully-masked KV blocks via ``@pl.when``.
Validated in interpret mode on CPU; the dry-run lowers the pure-JAX
path (kernel bodies are opaque to HloCostAnalysis anyway).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k,
                  seq_len, causal, sm_scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale           # [bq, d]
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, q.shape[-1]), jnp.float32)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)

    num_kb = seq_len // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k)].astype(jnp.float32)
        v = v_ref[0, pl.ds(kb * block_k, block_k)].astype(jnp.float32)
        s = q @ k.T                                        # [bq, bk]
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[:, None] + p @ v
        return m_new, l_new, acc_new

    if causal:
        # only blocks with k_start <= q_end participate
        last_kb = jnp.minimum(((qi + 1) * block_q - 1) // block_k + 1,
                              num_kb)
    else:
        last_kb = num_kb
    m, l, acc = jax.lax.fori_loop(0, last_kb, body, (m, l, acc))
    o_ref[0] = (acc / jnp.maximum(l[:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: [B, S, H, hd]; k/v: [B, S, Hkv, hd]. Returns [B, S, H, hd].

    GQA is handled by repeating KV heads logically via the index map
    (no materialized repeat).
    """
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    sm_scale = 1.0 / math.sqrt(hd)

    # layout: fold heads into the grid's leading dim
    qg = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd)
    kg = jnp.moveaxis(k, 2, 1).reshape(b * hkv, s, hd)
    vg = jnp.moveaxis(v, 2, 1).reshape(b * hkv, s, hd)

    grid = (b * h, s // block_q)
    kern = functools.partial(_flash_kernel, block_q=block_q,
                             block_k=block_k, seq_len=s, causal=causal,
                             sm_scale=sm_scale)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda i, j: (i, j, 0)),
            # KV: whole sequence for this head (streamed via pl.ds)
            pl.BlockSpec((1, s, hd), lambda i, j, g=g: (i // g, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i, j, g=g: (i // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        interpret=interpret,
    )(qg, kg, vg)
    return jnp.moveaxis(out.reshape(b, h, s, hd), 1, 2)
