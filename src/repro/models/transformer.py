"""Decoder-only LM assembly covering all assigned architecture families.

families:
* dense  — GQA or MLA attention + SwiGLU/GELU MLP          (llama3,
  minicpm-2b, minicpm3-4b, qwen2.5, paligemma backbone, musicgen)
* moe    — attention + ALB-adaptive MoE FFN                 (deepseek-moe,
  llama4-scout)
* ssm    — Mamba2 (SSD) blocks, attention-free              (mamba2-2.7b)
* hybrid — Mamba2 backbone + one SHARED attention block applied every
  ``attn_every`` layers (zamba2's weight-shared global mixer)

Layer stacks run under ``lax.scan`` with stacked [L, ...] params so HLO
size is O(1) in depth; hybrid nests: scan over groups of
(attn_every ssm layers + shared attention application).

Entry points:
* ``init(key, cfg)``                      -> params
* ``forward(params, cfg, tokens, ...)``   -> logits          (training)
* ``init_cache(cfg, batch, max_len)``     -> cache pytree (shapes)
* ``prefill(params, cfg, tokens, cache)`` -> (logits, cache)
* ``decode_step(params, cfg, token, cache, index)`` -> (logits, cache)

``shard_fn(name, x)`` lets the launcher inject
``with_sharding_constraint`` without the model importing mesh details.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba2 as M
from . import moe as MOE
from .layers import COMPUTE_DTYPE

_IDENT = lambda name, x: x

# H4: logits dtype. f32 is the safe default; bf16 halves the dominant
# activation (the [B, S, V] logits) for big-vocab archs — CE still
# reduces in f32 (logsumexp upcasts).
_LOGITS_DTYPE = jnp.float32


def set_logits_dtype(dt):
    global _LOGITS_DTYPE
    _LOGITS_DTYPE = dt


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg):
    """One layer's params (non-hybrid)."""
    p = {}
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        p["norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mamba"] = M.mamba2_init(ks[0], cfg)
        return p
    p["norm1"] = jnp.zeros((cfg.d_model,), jnp.float32)
    p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.attention == "mla":
        p["attn"] = L.mla_init(ks[0], cfg)
    else:
        p["attn"] = L.gqa_init(ks[0], cfg)
    if cfg.family == "moe":
        p["moe"] = MOE.moe_init(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def _stack_init(key, cfg, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_init(k, cfg))(keys)


def init(key, cfg):
    ks = jax.random.split(key, 8)
    p = {}
    vp = cfg.padded_vocab
    if cfg.num_codebooks > 1:
        p["embed"] = jax.vmap(
            lambda k: L._dense_init(k, (vp, cfg.d_model), 0.02)
        )(jax.random.split(ks[0], cfg.num_codebooks))
    else:
        p["embed"] = L._dense_init(ks[0], (vp, cfg.d_model), 0.02)
    if cfg.family == "hybrid":
        groups = cfg.num_layers // cfg.attn_every
        ssm_cfg = cfg
        p["layers"] = jax.vmap(
            lambda k: _stack_init(k, _as_ssm(cfg), cfg.attn_every)
        )(jax.random.split(ks[1], groups))
        # zamba2's shared global block: attention + MLP, ONE weight set
        # applied at every group boundary
        shared = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32),
                  "norm2": jnp.zeros((cfg.d_model,), jnp.float32),
                  "attn": L.gqa_init(ks[2], cfg),
                  "mlp": L.mlp_init(ks[4], cfg.d_model, cfg.d_ff, cfg.act)}
        p["shared_attn"] = shared
    else:
        p["layers"] = _stack_init(ks[1], cfg, cfg.num_layers)
    p["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            p["lm_head"] = jax.vmap(
                lambda k: L._dense_init(k, (cfg.d_model, vp))
            )(jax.random.split(ks[3], cfg.num_codebooks))
        else:
            p["lm_head"] = L._dense_init(ks[3], (cfg.d_model, vp))
    return p


def _as_ssm(cfg):
    import dataclasses
    return dataclasses.replace(cfg, family="ssm")


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _dense_block(p, x, cfg, *, positions, cache=None, cache_index=None,
                 shard_fn=_IDENT):
    attn_in = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if cfg.attention == "mla":
        a, new_cache = L.mla_apply(p["attn"], attn_in, cfg,
                                   positions=positions, cache=cache,
                                   cache_index=cache_index)
    else:
        a, new_cache = L.gqa_apply(p["attn"], attn_in, cfg,
                                   positions=positions, cache=cache,
                                   cache_index=cache_index)
    x = x + shard_fn("resid", a)
    ff_in = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        f, aux = MOE.moe_apply(p["moe"], ff_in, cfg, shard_fn=shard_fn)
    else:
        f, aux = L.mlp_apply(p["mlp"], ff_in, cfg.act), 0.0
    x = x + shard_fn("resid", f)
    return x, new_cache, aux


def _ssm_block(p, x, cfg, *, state=None, shard_fn=_IDENT):
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    out, new_state = M.mamba2_apply(p["mamba"], h, cfg, state=state)
    return x + shard_fn("resid", out), new_state


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed(p, cfg, tokens, prefix_emb=None):
    if cfg.num_codebooks > 1:
        # tokens: [B, S, num_codebooks] — sum codebook embeddings
        parts = [jnp.take(p["embed"][i].astype(COMPUTE_DTYPE),
                          tokens[..., i], axis=0)
                 for i in range(cfg.num_codebooks)]
        x = sum(parts)
    else:
        x = jnp.take(p["embed"].astype(COMPUTE_DTYPE), tokens, axis=0)
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    return x


def _head(p, cfg, x):
    xn = L.rms_norm(x, p["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = p["embed"].astype(COMPUTE_DTYPE).T
        return (xn.astype(COMPUTE_DTYPE) @ w).astype(_LOGITS_DTYPE)
    if cfg.num_codebooks > 1:
        return jnp.einsum("bsd,ndv->bsnv", xn.astype(COMPUTE_DTYPE),
                          p["lm_head"].astype(COMPUTE_DTYPE)
                          ).astype(_LOGITS_DTYPE)
    return (xn.astype(COMPUTE_DTYPE)
            @ p["lm_head"].astype(COMPUTE_DTYPE)).astype(_LOGITS_DTYPE)


# ---------------------------------------------------------------------------
# forward (training — no cache)
# ---------------------------------------------------------------------------

def forward(params, cfg, tokens, prefix_emb=None, shard_fn=_IDENT,
            remat: bool = True, unroll: bool = False):
    """tokens: [B, S] int32 ([B, S, ncb] for multi-codebook).
    Returns (logits, aux_loss).

    unroll=True replaces lax.scan with a python loop — used ONLY by the
    dry-run cost extraction (HloCostAnalysis counts scan bodies once)."""
    x = _embed(params, cfg, tokens, prefix_emb)
    x = shard_fn("hidden", x)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    if unroll:
        aux = 0.0
        if cfg.family == "hybrid":
            groups = cfg.num_layers // cfg.attn_every
            sa = params["shared_attn"]
            for gi in range(groups):
                for li in range(cfg.attn_every):
                    lp = jax.tree.map(lambda a: a[gi][li],
                                      params["layers"])
                    x, _ = _ssm_block(lp, x, cfg, shard_fn=shard_fn)
                attn_in = L.rms_norm(x, sa["norm1"], cfg.norm_eps)
                a, _ = L.gqa_apply(sa["attn"], attn_in, cfg,
                                   positions=positions)
                x = x + shard_fn("resid", a)
                ff_in = L.rms_norm(x, sa["norm2"], cfg.norm_eps)
                x = x + shard_fn("resid",
                                 L.mlp_apply(sa["mlp"], ff_in, cfg.act))
        else:
            for li in range(cfg.num_layers):
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                if cfg.family == "ssm":
                    x, _ = _ssm_block(lp, x, cfg, shard_fn=shard_fn)
                else:
                    x, _, a = _dense_block(lp, x, cfg,
                                           positions=positions,
                                           shard_fn=shard_fn)
                    aux = aux + a
        return _head(params, cfg, x), aux

    if cfg.family == "hybrid":
        def group_body(carry, gp):
            x, aux = carry
            def ssm_one(xx, lp):
                out, _ = _ssm_block(lp, xx, cfg, shard_fn=shard_fn)
                return out, None
            inner = jax.checkpoint(ssm_one) if remat else ssm_one
            x, _ = jax.lax.scan(inner, x, gp)
            sa = params["shared_attn"]
            attn_in = L.rms_norm(x, sa["norm1"], cfg.norm_eps)
            a, _ = L.gqa_apply(sa["attn"], attn_in, cfg,
                               positions=positions)
            x = x + shard_fn("resid", a)
            ff_in = L.rms_norm(x, sa["norm2"], cfg.norm_eps)
            x = x + shard_fn("resid", L.mlp_apply(sa["mlp"], ff_in, cfg.act))
            return (x, aux), None

        gbody = jax.checkpoint(group_body) if remat else group_body
        (x, aux), _ = jax.lax.scan(gbody, (x, 0.0), params["layers"])
    elif cfg.family == "ssm":
        def body(x, lp):
            out, _ = _ssm_block(lp, x, cfg, shard_fn=shard_fn)
            return out, None
        fbody = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fbody, x, params["layers"])
        aux = 0.0
    else:
        def body(carry, lp):
            x, aux = carry
            x, _, a = _dense_block(lp, x, cfg, positions=positions,
                                   shard_fn=shard_fn)
            return (x, aux + a), None
        fbody = jax.checkpoint(body) if remat else body
        (x, aux), _ = jax.lax.scan(fbody, (x, 0.0), params["layers"])

    logits = _head(params, cfg, x)
    return logits, aux


# ---------------------------------------------------------------------------
# inference: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch, max_len):
    """ShapeDtypeStruct pytree of the decode state (KV caches / SSM
    states), stacked over layers."""
    def stack(shape_tree, n):
        return jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((n, *sd.shape), sd.dtype),
            shape_tree)

    if cfg.family == "hybrid":
        groups = cfg.num_layers // cfg.attn_every
        ssm = stack(stack(M.mamba2_state_shape(cfg, batch),
                          cfg.attn_every), groups)
        attn = stack(L.gqa_cache_shape(cfg, batch, max_len), groups)
        return {"ssm": ssm, "attn": attn,
                "index": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.family == "ssm":
        return {"ssm": stack(M.mamba2_state_shape(cfg, batch),
                             cfg.num_layers),
                "index": jax.ShapeDtypeStruct((), jnp.int32)}
    shape = (L.mla_cache_shape(cfg, batch, max_len)
             if cfg.attention == "mla"
             else L.gqa_cache_shape(cfg, batch, max_len))
    return {"kv": stack(shape, cfg.num_layers),
            "index": jax.ShapeDtypeStruct((), jnp.int32)}


def zeros_cache(cfg, batch, max_len):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        init_cache(cfg, batch, max_len))


def _step(params, cfg, tokens, cache, cache_index, prefix_emb=None,
          shard_fn=_IDENT, unroll: bool = False):
    """Shared prefill/decode body: consumes + updates the cache."""
    x = _embed(params, cfg, tokens, prefix_emb)
    x = shard_fn("hidden", x)
    b, s, _ = x.shape
    positions = cache_index + jnp.arange(s, dtype=jnp.int32)[None, :]

    if unroll:
        return _step_unrolled(params, cfg, x, cache, cache_index,
                              positions, shard_fn)

    if cfg.family == "hybrid":
        def gbody(carry, inp):
            x = carry
            gp, ssm_state, attn_cache = inp
            def ssm_one(xx, inp2):
                lp, st = inp2
                out, new_st = _ssm_block(lp, xx, cfg, state=st,
                                         shard_fn=shard_fn)
                return out, new_st
            x, new_ssm = jax.lax.scan(ssm_one, x, (gp, ssm_state))
            sa = params["shared_attn"]
            attn_in = L.rms_norm(x, sa["norm1"], cfg.norm_eps)
            a, new_kv = L.gqa_apply(sa["attn"], attn_in, cfg,
                                    positions=positions, cache=attn_cache,
                                    cache_index=cache_index)
            x = x + shard_fn("resid", a)
            ff_in = L.rms_norm(x, sa["norm2"], cfg.norm_eps)
            x = x + shard_fn("resid", L.mlp_apply(sa["mlp"], ff_in, cfg.act))
            return x, (new_ssm, new_kv)
        x, (new_ssm, new_attn) = jax.lax.scan(
            gbody, x, (params["layers"], cache["ssm"], cache["attn"]))
        new_cache = {"ssm": new_ssm, "attn": new_attn,
                     "index": cache_index + s}
    elif cfg.family == "ssm":
        def body(x, inp):
            lp, st = inp
            out, new_st = _ssm_block(lp, x, cfg, state=st,
                                     shard_fn=shard_fn)
            return out, new_st
        x, new_ssm = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        new_cache = {"ssm": new_ssm, "index": cache_index + s}
    else:
        def body(x, inp):
            lp, kv = inp
            x, new_kv, _ = _dense_block(lp, x, cfg, positions=positions,
                                        cache=kv, cache_index=cache_index,
                                        shard_fn=shard_fn)
            return x, new_kv
        x, new_kv = jax.lax.scan(body, x, (params["layers"], cache["kv"]))
        new_cache = {"kv": new_kv, "index": cache_index + s}

    logits = _head(params, cfg, x[:, -1:])
    return logits, new_cache


def _step_unrolled(params, cfg, x, cache, cache_index, positions,
                   shard_fn):
    """python-loop twin of _step for the dry-run cost extraction."""
    def idx(tree, i):
        return jax.tree.map(lambda a: a[i], tree)

    def set_idx(tree, new, i):
        return jax.tree.map(lambda a, n: a.at[i].set(n), tree, new)

    if cfg.family == "hybrid":
        groups = cfg.num_layers // cfg.attn_every
        sa = params["shared_attn"]
        new_cache = {"ssm": cache["ssm"], "attn": cache["attn"],
                     "index": cache_index + x.shape[1]}
        for gi in range(groups):
            for li in range(cfg.attn_every):
                lp = jax.tree.map(lambda a: a[gi][li], params["layers"])
                st = jax.tree.map(lambda a: a[gi][li], cache["ssm"])
                x, nst = _ssm_block(lp, x, cfg, state=st,
                                    shard_fn=shard_fn)
                new_cache["ssm"] = jax.tree.map(
                    lambda a, n, g=gi, l=li: a.at[g, l].set(n),
                    new_cache["ssm"], nst)
            attn_in = L.rms_norm(x, sa["norm1"], cfg.norm_eps)
            a, nkv = L.gqa_apply(sa["attn"], attn_in, cfg,
                                 positions=positions,
                                 cache=idx(cache["attn"], gi),
                                 cache_index=cache_index)
            new_cache["attn"] = set_idx(new_cache["attn"], nkv, gi)
            x = x + shard_fn("resid", a)
            ff_in = L.rms_norm(x, sa["norm2"], cfg.norm_eps)
            x = x + shard_fn("resid",
                             L.mlp_apply(sa["mlp"], ff_in, cfg.act))
    elif cfg.family == "ssm":
        new_cache = {"ssm": cache["ssm"],
                     "index": cache_index + x.shape[1]}
        for li in range(cfg.num_layers):
            lp = idx(params["layers"], li)
            st = idx(cache["ssm"], li)
            x, nst = _ssm_block(lp, x, cfg, state=st, shard_fn=shard_fn)
            new_cache["ssm"] = set_idx(new_cache["ssm"], nst, li)
    else:
        new_cache = {"kv": cache["kv"], "index": cache_index + x.shape[1]}
        for li in range(cfg.num_layers):
            lp = idx(params["layers"], li)
            kv = idx(cache["kv"], li)
            x, nkv, _ = _dense_block(lp, x, cfg, positions=positions,
                                     cache=kv, cache_index=cache_index,
                                     shard_fn=shard_fn)
            new_cache["kv"] = set_idx(new_cache["kv"], nkv, li)
    logits = _head(params, cfg, x[:, -1:])
    return logits, new_cache


def prefill(params, cfg, tokens, cache, prefix_emb=None, shard_fn=_IDENT,
            unroll: bool = False):
    """Fill the cache from a prompt; SSM prefill runs the chunked scan
    then keeps only the final state (sub-quadratic)."""
    if cfg.family in ("ssm", "hybrid"):
        # stateful path needs s==1 per step for the SSD step; prefill
        # instead runs the chunked scan statelessly and rebuilds state.
        return _prefill_ssm(params, cfg, tokens, cache, shard_fn,
                            unroll=unroll)
    return _step(params, cfg, tokens, cache, jnp.int32(0),
                 prefix_emb=prefix_emb, shard_fn=shard_fn, unroll=unroll)


def _prefill_ssm(params, cfg, tokens, cache, shard_fn=_IDENT,
                 unroll: bool = False):
    x = _embed(params, cfg, tokens)
    x = shard_fn("hidden", x)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    def run_mamba(lp, xx):
        h = L.rms_norm(xx, lp["norm"], cfg.norm_eps)
        out, state = M.mamba2_apply(lp["mamba"], h, cfg, state=None,
                                    return_state=True)
        return xx + shard_fn("resid", out.astype(xx.dtype)), state

    if unroll:
        return _prefill_ssm_unrolled(params, cfg, x, cache, positions,
                                     run_mamba, shard_fn)

    if cfg.family == "ssm":
        def body(x, lp):
            return run_mamba(lp, x)
        x, new_ssm = jax.lax.scan(body, x, params["layers"])
        new_cache = {"ssm": new_ssm, "index": jnp.int32(s)}
    else:  # hybrid
        def gbody(carry, inp):
            x = carry
            gp, attn_cache = inp
            x, new_ssm = jax.lax.scan(lambda xx, lp: run_mamba(lp, xx),
                                      x, gp)
            sa = params["shared_attn"]
            attn_in = L.rms_norm(x, sa["norm1"], cfg.norm_eps)
            a, new_kv = L.gqa_apply(sa["attn"], attn_in, cfg,
                                    positions=positions, cache=attn_cache,
                                    cache_index=jnp.int32(0))
            x = x + shard_fn("resid", a)
            ff_in = L.rms_norm(x, sa["norm2"], cfg.norm_eps)
            x = x + shard_fn("resid", L.mlp_apply(sa["mlp"], ff_in, cfg.act))
            return x, (new_ssm, new_kv)
        x, (new_ssm, new_attn) = jax.lax.scan(
            gbody, x, (params["layers"], cache["attn"]))
        new_cache = {"ssm": new_ssm, "attn": new_attn,
                     "index": jnp.int32(s)}
    logits = _head(params, cfg, x[:, -1:])
    return logits, new_cache


def _prefill_ssm_unrolled(params, cfg, x, cache, positions, run_mamba,
                          shard_fn):
    s = x.shape[1]
    if cfg.family == "ssm":
        states = []
        for li in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            x, st = run_mamba(lp, x)
            states.append(st)
        new_ssm = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        new_cache = {"ssm": new_ssm, "index": jnp.int32(s)}
    else:
        groups = cfg.num_layers // cfg.attn_every
        sa = params["shared_attn"]
        gstates, kvs = [], []
        for gi in range(groups):
            lstates = []
            for li in range(cfg.attn_every):
                lp = jax.tree.map(lambda a: a[gi][li], params["layers"])
                x, st = run_mamba(lp, x)
                lstates.append(st)
            gstates.append(jax.tree.map(lambda *xs: jnp.stack(xs),
                                        *lstates))
            attn_in = L.rms_norm(x, sa["norm1"], cfg.norm_eps)
            a, nkv = L.gqa_apply(
                sa["attn"], attn_in, cfg, positions=positions,
                cache=jax.tree.map(lambda c: c[gi], cache["attn"]),
                cache_index=jnp.int32(0))
            kvs.append(nkv)
            x = x + shard_fn("resid", a)
            ff_in = L.rms_norm(x, sa["norm2"], cfg.norm_eps)
            x = x + shard_fn("resid",
                             L.mlp_apply(sa["mlp"], ff_in, cfg.act))
        new_cache = {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs),
                                         *gstates),
                     "attn": jax.tree.map(lambda *xs: jnp.stack(xs),
                                          *kvs),
                     "index": jnp.int32(s)}
    logits = _head(params, cfg, x[:, -1:])
    return logits, new_cache


def decode_step(params, cfg, token, cache, shard_fn=_IDENT,
                unroll: bool = False):
    """token: [B, 1] (or [B, 1, ncb]). One autoregressive step."""
    return _step(params, cfg, token, cache, cache["index"],
                 shard_fn=shard_fn, unroll=unroll)
