"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM's
schedule — minicpm-2b's assignment note)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, stable: int, decay: int,
                 min_ratio: float = 0.01):
    """Warmup-Stable-Decay (arXiv:2404.06395): flat LR, then a short
    exponential-ish decay tail."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        in_decay = step > (warmup + stable)
        dprog = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = base_lr * (min_ratio ** dprog)
        return jnp.where(step < warmup, warm,
                         jnp.where(in_decay, dec, base_lr))
    return lr
