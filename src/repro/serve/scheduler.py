"""Admission policy: which queries enter which slots each round.

The scheduler is pure policy — it sees per-slot occupancy views and
the pending depth, and returns a :class:`Decision`; the engine applies
it to device state.  Keeping it side-effect free makes admission
deterministic and directly testable (DESIGN.md section 8).

Two rules:

* **FIFO admission.**  Free slots are filled in ascending slot order
  from the front of the pending queue (lowest qid first).  Same
  submissions => same admission sequence, always.
* **Round-budget fairness.**  With ``round_budget=k``, a query that
  has held its slot for k consecutive rounds *while other queries
  wait* is preempted: its ``[V]`` labels/frontier rows are snapshotted
  to the host and it re-enters the FIFO at the back.  Restoring the
  snapshot on re-admission is exact, so preemption never perturbs
  results — it only reorders rounds — and a giant-diameter query can
  delay the queue by at most ``k`` rounds per visit instead of its
  whole eccentricity.  ``round_budget=None`` disables preemption
  (run-to-completion).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class SlotView:
    """What the scheduler may know about one slot: its index, the
    occupying query (None = idle), and how many consecutive rounds that
    query has held the slot since (re-)admission."""
    slot: int
    qid: Optional[int]
    slot_rounds: int


@dataclasses.dataclass(frozen=True)
class Decision:
    """One round's admission plan: ``preempt`` lists slots whose
    occupant yields to the queue; ``admit`` lists the slots to fill
    from the pending FIFO (both in the order the engine must apply
    them)."""
    preempt: tuple
    admit: tuple


class Scheduler:
    """Deterministic FIFO admission with optional round-budget
    preemption (see module docstring)."""

    def __init__(self, round_budget: Optional[int] = None) -> None:
        if round_budget is not None and round_budget < 1:
            raise ValueError("round_budget must be >= 1 (or None)")
        self.round_budget = round_budget

    def plan(self, slots: List[SlotView], pending: int) -> Decision:
        """Decide this round's preemptions and admissions.

        Preempt only what the queue actually needs: at most
        ``pending - idle`` over-budget slots (idle slots absorb queued
        work for free, and preempting more than ``pending`` would idle
        slots), longest-residency first (ties: lowest slot) so the
        query that has delayed the queue the longest yields first.
        Then admit into every free slot, ascending.
        """
        idle = [s.slot for s in slots if s.qid is None]
        preempt: list = []
        need = pending - len(idle)
        if self.round_budget is not None and need > 0:
            over = [s for s in slots if s.qid is not None
                    and s.slot_rounds >= self.round_budget]
            over.sort(key=lambda s: (-s.slot_rounds, s.slot))
            preempt = [s.slot for s in over[:need]]
        free = sorted(idle + preempt)
        n_admit = min(len(free), pending + len(preempt))
        return Decision(preempt=tuple(preempt),
                        admit=tuple(free[:n_admit]))
