"""Table 2 under the paper's own cost model (BSP max-loaded tile).

A single-core CPU cannot exhibit *parallel* load imbalance in
wall-clock: it executes total work, while a real GPU/TPU round is gated
by the MAX-loaded thread block / tile (the paper's Figure 1/5 point:
block 0 processes 35M edges while the rest idle).  This benchmark
therefore evaluates strategies under the BSP cost model the paper's
analysis uses:

    simulated_round_time = max over tiles of (edges assigned to tile)
    simulated_exec_time  = sum over rounds of simulated_round_time

using the per-tile load instrumentation (`RoundStats.tile_loads_*`,
64 tiles).  Wall-clock CPU numbers are reported separately in
table2_strategies (with the caveat recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import numpy as np

from repro.core.balancer import BalancerConfig
from repro.core import graph as G
from repro.core.apps import bfs, sssp, cc, kcore

from .common import bench_graphs, symmetrized, emit


def simulated_time(stats):
    total = 0
    for st in stats:
        loads = st.tile_loads_twc + st.tile_loads_lb
        total += int(loads.max())
    return max(total, 1)


def run(scale: int = 14):
    # skewed, dedup-free power-law graph: hubs keep their multi-edges
    # (the paper's rmat inputs have hub degree ~ E * skew^scale)
    rng = np.random.default_rng(1)
    n, m = 1 << scale, 16 << scale
    a, b, c = 0.65, 0.15, 0.15
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for _ in range(scale):
        r = rng.random(m)
        quad = np.select([r < a, r < a + b, r < a + b + c], [0, 1, 2], 3)
        src = (src << 1) | (quad >= 2)
        dst = (dst << 1) | (quad & 1)
    w = rng.integers(1, 101, size=m)
    hub = G.from_edge_list(src, dst, n, weights=w, dedup=False)

    graphs = {"rmat_hub": hub, "road": bench_graphs(scale)["road"]}
    out = {}
    for gname, g in graphs.items():
        s0 = G.highest_out_degree_vertex(g) if gname != "road" else 0
        sym = symmetrized(g)
        apps = {
            "bfs": lambda cfg: bfs(g, s0, cfg, max_rounds=300,
                                   collect_stats=True),
            "sssp": lambda cfg: sssp(g, s0, cfg, max_rounds=300,
                                     collect_stats=True),
            "cc": lambda cfg: cc(sym, cfg, max_rounds=300,
                                 collect_stats=True),
            "kcore": lambda cfg: kcore(sym, 10, cfg, max_rounds=300,
                                       collect_stats=True),
        }
        for aname, fn in apps.items():
            times = {}
            for strat in ["twc", "alb"]:
                cfg = BalancerConfig(strategy=strat, threshold=1024)
                res = fn(cfg)
                times[strat] = simulated_time(res.stats)
            speedup = times["twc"] / times["alb"]
            out[(gname, aname)] = speedup
            emit(f"table2sim/{gname}/{aname}", times["alb"] * 1e-6,
                 f"alb_speedup_vs_twc={speedup:.2f}x "
                 f"(BSP max-tile cost model)")
    return out


if __name__ == "__main__":
    run()
