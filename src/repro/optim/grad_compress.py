"""int8 gradient compression for the data-parallel reduce.

At 1000+ nodes the cross-pod gradient all-reduce rides the slow (DCN)
axis; block-scaled int8 quantization cuts those bytes 4x vs f32 (2x vs
bf16).  Scheme: per-block (last dim tiles of 256) absmax scale,
symmetric int8 quantize -> all-reduce in int32 (sums of int8 fit
easily) -> dequantize with the max scale.  The estimator is unbiased
per block up to rounding; 0.5-ulp stochastic rounding is left as a
config knob (deterministic rounding keeps tests exact).

Used inside shard_map over the mesh's data axes; see
tests/test_grad_compress.py for the numerical-error bound test.  The
block-absmax padding/scaling primitives are the shared idiom of
``repro.core.wire`` (the sync-payload codec layer) and are imported
from there.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.wire import BLOCK, block_absmax_scale, pad_to_block

_pad_to_block = pad_to_block      # back-compat alias (pre-wire name)


def quantize(x):
    """x: any-shape f32/bf16 -> (int8 blocks, f32 scales, meta)."""
    blocks, npad = pad_to_block(x.astype(jnp.float32))
    scale = block_absmax_scale(blocks)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], (x.shape, npad)


def dequantize(q, scale, meta):
    shape, npad = meta
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    if npad:
        flat = flat[:-npad] if npad else flat
    return flat.reshape(shape)


def compressed_psum(tree, axis_name):
    """All-reduce a gradient pytree over ``axis_name`` in int8.

    Each participant quantizes with its local scale, the int8 payloads
    are summed (psum over int32), scales are max-reduced, and the sum is
    dequantized with the max scale — a standard 1-bit-Adam-family
    approximation whose error is bounded by the scale quantum.
    """
    def one(g):
        q, scale, meta = quantize(g)
        smax = jax.lax.pmax(scale, axis_name)
        # requantize against the GLOBAL scale so summation is coherent
        blocks, npad = pad_to_block(g.astype(jnp.float32))
        qg = jnp.clip(jnp.round(blocks / smax[:, None]), -127,
                      127).astype(jnp.int32)
        total = jax.lax.psum(qg, axis_name)
        out = total.astype(jnp.float32) * smax[:, None]
        flat = out.reshape(-1)
        if npad:
            flat = flat[:-npad]
        return flat.reshape(g.shape).astype(g.dtype)

    return jax.tree.map(one, tree)
