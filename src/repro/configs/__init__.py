"""Config registry: --arch <id> resolution."""
from .base import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                   ShapeConfig, SHAPES, shape_by_name, applicable_shapes)

from . import (zamba2_2p7b, minicpm3_4b, llama3_8b, minicpm_2b,
               qwen2p5_14b, paligemma_3b, mamba2_2p7b, deepseek_moe_16b,
               llama4_scout_17b, musicgen_large)

_MODULES = {
    "zamba2-2.7b": zamba2_2p7b,
    "minicpm3-4b": minicpm3_4b,
    "llama3-8b": llama3_8b,
    "minicpm-2b": minicpm_2b,
    "qwen2.5-14b": qwen2p5_14b,
    "paligemma-3b": paligemma_3b,
    "mamba2-2.7b": mamba2_2p7b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "llama4-scout-17b-a16e": llama4_scout_17b,
    "musicgen-large": musicgen_large,
}

ARCH_IDS = tuple(_MODULES.keys())


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE


__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
           "ShapeConfig", "SHAPES", "shape_by_name", "applicable_shapes",
           "ARCH_IDS", "get_config", "get_smoke_config"]
