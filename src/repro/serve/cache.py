"""LRU result cache of the serving layer.

Point queries are deterministic — the batched engine guarantees every
served query is bitwise equal to its standalone run — so a repeat
(graph, app, source) lookup can be answered from memory without
touching the device.  Keys are ``(graph_id, app, source, strategy)``
where ``strategy`` is the frozen :class:`BalancerConfig` (hashable by
construction): results are strategy-independent by the parity
invariant, but keying on the config keeps the cache trivially correct
if a future strategy ever trades exactness for speed, and lets A/B
deployments coexist (DESIGN.md section 8).

Re-registering a graph id invalidates every entry for that id — the
binding ``graph_id -> CSR`` changed, so cached labels may be stale.

Published arrays are **read-only**: ``put`` freezes the ndarray
(``setflags(write=False)``) before it becomes shared state.  The same
object is handed to every future ``get`` — and, via the engine, to the
primary's ``poll().result`` and all coalesced followers — so a caller
mutating a result in place would otherwise silently corrupt every
future cache hit.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional

import numpy as np


class ResultCache:
    """Bounded LRU map ``(graph_id, app, source, strategy) ->
    labels[V]`` with hit/miss counters; ``capacity=0`` disables
    caching entirely (every ``get`` is a miss)."""

    def __init__(self, capacity: int = 256) -> None:
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(graph_id: str, app: str, source: int,
            strategy: Hashable) -> tuple:
        """The canonical cache key (DESIGN.md section 8)."""
        return (graph_id, app, int(source), strategy)

    def get(self, graph_id: str, app: str, source: int,
            strategy: Hashable) -> Optional[np.ndarray]:
        """Cached labels for the query, refreshing its LRU position;
        None (and a counted miss) when absent."""
        k = self.key(graph_id, app, source, strategy)
        if k not in self._entries:
            self.misses += 1
            return None
        self._entries.move_to_end(k)
        self.hits += 1
        return self._entries[k]

    def put(self, graph_id: str, app: str, source: int,
            strategy: Hashable, labels: np.ndarray) -> None:
        """Insert/refresh an entry, evicting the least recently used
        entry when over capacity.  The array is frozen
        (``setflags(write=False)``) — it becomes shared state served to
        every future hit, so in-place mutation must raise rather than
        corrupt the cache."""
        if self.capacity == 0:
            return
        labels.setflags(write=False)
        k = self.key(graph_id, app, source, strategy)
        self._entries[k] = labels
        self._entries.move_to_end(k)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def invalidate_graph(self, graph_id: str) -> int:
        """Drop every entry of ``graph_id`` (its CSR binding changed);
        returns how many entries were dropped."""
        stale = [k for k in self._entries if k[0] == graph_id]
        for k in stale:
            del self._entries[k]
        return len(stale)

    @property
    def hit_rate(self) -> float:
        """hits / (hits + misses); 0.0 before any lookup."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._entries)
