"""End-to-end LM training driver (deliverable b): train a ~100M-param
llama3-family model for a few hundred steps on CPU with checkpointing.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import get_config          # noqa: E402
from repro.launch.train import main as train  # noqa: E402
import repro.configs.llama3_8b as l3          # noqa: E402


def make_100m():
    """~100M-param llama3-family config (12L, d=768)."""
    return dataclasses.replace(
        l3.CONFIG, name="llama3-100m", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32000,
        head_dim=64)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # register the 100M config in place of the smoke config
    import repro.launch.train as TR
    cfg = make_100m()
    TR.get_smoke_config = lambda arch: cfg

    with tempfile.TemporaryDirectory() as d:
        loss = train(["--arch", "llama3-8b", "--smoke",
                      "--steps", str(args.steps),
                      "--batch", str(args.batch),
                      "--seq", str(args.seq),
                      "--schedule", "wsd",
                      "--ckpt-dir", d, "--ckpt-every", "100",
                      "--log-every", "20"])
    print(f"final loss: {loss:.4f}")
