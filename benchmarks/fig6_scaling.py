"""Fig 6/10 analogue: multi-device scaling of D-IrGL(TWC) vs
D-IrGL(ALB) — BSP rounds over partitioned graphs, 1..8 devices, under
both sync substrates (``replicated`` all-reduce vs ``mirror``
boundary exchange, DESIGN.md section 6).

Besides the CSV rows, writes ``benchmarks/out/fig6_scaling.json`` with
per-round communication volume so the perf trajectory tracks what
actually crosses the interconnect, not just wall clock.  Every row
carries the wire codec name (``wire``, DESIGN.md section 14) plus the
per-round logical volume (``bytes_synced_per_round``, index side
included), the post-encode volume (``bytes_wire_per_round``), and the
per-round compression ratio ``bytes_wire / bytes_synced``; rows also
carry ``mode`` (host vs fused round loop, DESIGN.md section 11) and
``host_transfers`` — the number of blocking device->host sync points
the traversal performed.

Timed rows run the default ``identity`` codec; the codec-comparison
rows (``delta`` / ``bitmap``) are instrumented-only (host mode), since
the compression trajectory is structural, not a wall-clock claim.
``quantize`` is absent by construction: sssp's min-combine declares no
safe narrowing, so the config-time raise is asserted instead (the
``--smoke`` CI run keeps that gate exercised).

Re-execs itself with a forced host device count so the multi-device
run never contaminates the parent process's single-device state.
``--smoke``: a small-graph, two-mesh subset for the benchmark-smoke CI
job.
"""
from __future__ import annotations

import os
import subprocess
import sys

MAX_DEV = 8
OUT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "out", "fig6_scaling.json")

WIRE_CODECS = ["identity", "delta", "bitmap"]


def run(smoke: bool = False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{MAX_DEV}").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    argv = [sys.executable, "-m", "benchmarks.fig6_scaling", "--inner"]
    if smoke:
        argv.append("--smoke")
    r = subprocess.run(argv, env=env, cwd=root,
                       capture_output=True, text=True, timeout=3600)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr[-3000:])
        raise RuntimeError("fig6 inner run failed")


def _comm_rows(gluon, sg, mesh, src, cfg_base, sync, meta, max_rounds):
    """One instrumented (host-mode) run per wire codec: the comm-volume
    trajectory the ROADMAP asks for, as (wire -> per-round byte lists
    and ratios)."""
    import dataclasses
    out = {}
    for wname in WIRE_CODECS:
        cfg = dataclasses.replace(cfg_base, wire=wname)
        _, _, _, stats = gluon.sssp_distributed(
            sg, mesh, src, cfg, max_rounds=max_rounds,
            collect_stats=True, sync=sync, meta=meta)
        logical = [int(sum(st.bytes_synced for st in pr))
                   for pr in stats]
        wired = [int(sum(st.bytes_wire for st in pr)) for pr in stats]
        out[wname] = dict(
            bytes_synced_per_round=logical,
            bytes_wire_per_round=wired,
            compression_ratio_per_round=[
                (w / b) if b else 1.0 for b, w in zip(logical, wired)],
            bytes_synced_total=sum(logical),
            bytes_wire_total=sum(wired))
    return out


def inner(smoke: bool = False):
    import json
    import time
    from repro.core import graph as G
    from repro.core.partition import partition
    from repro.core import gluon
    from repro.core.balancer import BalancerConfig, host_transfer_count
    from .common import emit

    scale, ef = (10, 8) if smoke else (13, 16)
    g = G.rmat(scale, ef, seed=1)
    src = G.highest_out_degree_vertex(g)
    dev_counts = [2, 4] if smoke else [1, 2, 4, 8]
    strategies = ["alb"] if smoke else ["twc", "alb"]
    max_rounds = 200

    # config-time gate: quantize on sssp (no declared narrowing) must
    # refuse to run — keep that contract exercised wherever fig6 runs
    mesh0 = gluon.device_mesh(dev_counts[0])
    sg0, meta0 = partition(g, dev_counts[0], "oec")
    try:
        gluon.sssp_distributed(sg0, mesh0, src,
                               BalancerConfig(wire="quantize"),
                               sync="mirror", meta=meta0)
    except ValueError:
        pass
    else:
        raise AssertionError(
            "wire='quantize' must raise at config time for sssp")

    rows = []
    for ndev in dev_counts:
        mesh = gluon.device_mesh(ndev)
        sg, meta = partition(g, ndev, "oec")
        for strat in strategies:
            cfg = BalancerConfig(strategy=strat, threshold=1024)
            for sync in ["replicated", "mirror"]:
                # instrumented runs: comm volume per round, one per
                # codec (host mode only — fused+collect_stats is
                # rejected)
                comm = _comm_rows(gluon, sg, mesh, src, cfg, sync,
                                  meta, max_rounds)
                for mode in ["host", "fused"]:
                    # warmup (compile)
                    gluon.sssp_distributed(sg, mesh, src, cfg,
                                           max_rounds=max_rounds,
                                           sync=sync, meta=meta,
                                           mode=mode)
                    t_sync = host_transfer_count()
                    t0 = time.perf_counter()
                    labels, rounds, _ = gluon.sssp_distributed(
                        sg, mesh, src, cfg, max_rounds=max_rounds,
                        sync=sync, meta=meta, mode=mode)
                    secs = time.perf_counter() - t0
                    ht = host_transfer_count() - t_sync
                    c = comm["identity"]
                    emit(f"fig6/sssp/{strat}/gpus{ndev}/{sync}/{mode}",
                         secs,
                         f"rounds={rounds};"
                         f"bytes_total={c['bytes_synced_total']};"
                         f"ht={ht}")
                    rows.append(dict(
                        app="sssp", strategy=strat, num_devices=ndev,
                        sync=sync, mode=mode, wire="identity",
                        seconds=secs, rounds=rounds, host_transfers=ht,
                        replication_factor=meta.replication_factor,
                        **c))
                # codec-comparison rows: structural, untimed
                for wname in WIRE_CODECS[1:]:
                    rows.append(dict(
                        app="sssp", strategy=strat, num_devices=ndev,
                        sync=sync, mode="host", wire=wname,
                        seconds=None, rounds=len(
                            comm[wname]["bytes_synced_per_round"]),
                        host_transfers=None,
                        replication_factor=meta.replication_factor,
                        **comm[wname]))
    os.makedirs(os.path.dirname(OUT_JSON), exist_ok=True)
    with open(OUT_JSON, "w") as f:
        json.dump(dict(
            figure="fig6_scaling",
            smoke=smoke,
            graph=dict(kind="rmat", scale=scale, edge_factor=ef,
                       num_vertices=g.num_vertices,
                       num_edges=g.num_edges),
            wire_codecs=WIRE_CODECS,
            replicated_baseline_bytes_per_round={
                str(d): g.num_vertices * 4 * d for d in dev_counts},
            rows=rows), f, indent=2)
    print(f"# wrote {OUT_JSON}", flush=True)


if __name__ == "__main__":
    if "--inner" in sys.argv:
        inner(smoke="--smoke" in sys.argv)
    else:
        run(smoke="--smoke" in sys.argv)
