"""ALB-adaptive MoE dispatch: behavioural tests of the paper's
inspector-executor transplanted to token routing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe as MOE


def mk_cfg(adaptive, num_experts=8, top_k=2, cap=1.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=num_experts, top_k=top_k,
                      num_shared_experts=0, d_expert=16,
                      capacity_factor=cap, adaptive=adaptive))


def _routed_fraction(cfg, x, params):
    """Fraction of token-slots that land inside capacity."""
    import jax
    m = cfg.moe
    t = x.shape[0] * x.shape[1]
    xf = x.reshape(t, -1)
    logits = (xf @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, _, _, keep, _ = MOE.dispatch_plan(probs, m, t)
    return float(jnp.mean(keep.astype(jnp.float32)))


def _skewed_input(cfg, key, b=4, s=64):
    """Inputs crafted so the router is extremely imbalanced: all tokens
    nearly identical -> one hot expert (the power-law analogue)."""
    base = jax.random.normal(key, (1, 1, cfg.d_model))
    noise = 0.01 * jax.random.normal(jax.random.fold_in(key, 1),
                                     (b, s, cfg.d_model))
    return (base + noise).astype(jnp.float32)


def test_adaptive_rescues_overflow_tokens():
    key = jax.random.PRNGKey(0)
    cfg_a, cfg_b = mk_cfg(True), mk_cfg(False)
    params = MOE.moe_init(key, cfg_a)
    x = _skewed_input(cfg_a, jax.random.PRNGKey(2))
    kept_adaptive = _routed_fraction(cfg_a, x, params)
    kept_static = _routed_fraction(cfg_b, x, params)
    # the executor re-deals overflow to second choices: strictly more
    # tokens survive under skew
    assert kept_adaptive > kept_static
    assert kept_static < 0.5          # skew really does overflow


def test_adaptive_noop_when_balanced():
    """Inspector: balanced routing -> identical output with/without the
    executor (the paper's 'negligible overhead' claim, MoE edition)."""
    key = jax.random.PRNGKey(0)
    cfg_a, cfg_s = mk_cfg(True, cap=4.0), mk_cfg(False, cap=4.0)
    params = MOE.moe_init(key, cfg_a)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg_a.d_model))
    out_a, aux_a = MOE.moe_apply(params, x, cfg_a)
    out_s, aux_s = MOE.moe_apply(params, x, cfg_s)
    np.testing.assert_allclose(np.asarray(out_a, np.float32),
                               np.asarray(out_s, np.float32),
                               rtol=1e-2, atol=1e-2)


def test_moe_output_finite_and_shaped():
    cfg = mk_cfg(True)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = MOE.moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 0.0


def test_moe_grads_flow_through_dispatch():
    cfg = mk_cfg(True)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))

    def loss(p):
        out, aux = MOE.moe_apply(p, x, cfg)
        return jnp.sum(out.astype(jnp.float32) ** 2) + aux

    g = jax.grad(loss)(params)
    for k, v in g.items():
        leaves = jax.tree.leaves(v)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves), k
    # experts that received tokens must have nonzero grads
    assert float(jnp.abs(g["w_up"]).max()) > 0


def test_pallas_dispatch_matches_jnp_in_moe():
    cfg = mk_cfg(True)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out_a, _ = MOE.moe_apply(params, x, cfg, use_pallas_dispatch=False)
    out_b, _ = MOE.moe_apply(params, x, cfg, use_pallas_dispatch=True)
    np.testing.assert_allclose(np.asarray(out_a, np.float32),
                               np.asarray(out_b, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_grouped_dispatch_matches_global_when_ample_capacity():
    """GShard-style grouped dispatch == global dispatch when nothing
    overflows (cap factor 4)."""
    import dataclasses
    cfg1 = mk_cfg(True, cap=4.0)
    cfgg = dataclasses.replace(
        cfg1, moe=dataclasses.replace(cfg1.moe, dispatch_groups=4))
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg1)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32, cfg1.d_model))
    out1, _ = MOE.moe_apply(params, x, cfg1)
    outg, _ = MOE.moe_apply(params, x, cfgg)
    np.testing.assert_allclose(np.asarray(out1, np.float32),
                               np.asarray(outg, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_grouped_dispatch_trains():
    import dataclasses
    cfg = mk_cfg(True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=4))
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

    def loss(p):
        out, aux = MOE.moe_apply(p, x, cfg)
        return jnp.sum(out.astype(jnp.float32) ** 2) + aux

    g = jax.grad(loss)(params)
    assert all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree.leaves(g))
