"""The paper's five applications: bfs, sssp, cc, pagerank, kcore.

Each driver runs the data-driven round structure of Section 2.1:
process the *current* worklist, collect the *next* worklist from label
changes, repeat until empty.  All of them are thin wrappers over the
balancer round so every application automatically benefits from
whichever load-balancing strategy is configured — the compiler-level
reuse the paper gets from IrGL.

``mode`` selects the round implementation (DESIGN.md sections 3, 11):

* ``"host"`` — ``balancer.relax``: per-round host decisions + bucketed
  jit shapes (the single-device wall-clock configuration);
* ``"spmd"`` — ``balancer.relax_spmd``: the fully-jit static-capacity
  round used inside ``shard_map`` by the distributed runtime, here run
  on one device so its behaviour (including the jit-safe RoundStats)
  can be measured and tested against the host round;
* ``"fused"`` — ``balancer.run_fused``: the whole traversal as ONE
  ``lax.while_loop`` with the inspector and the direction rule on
  device — zero host syncs between the initial dispatch and the final
  label fetch (``AppResult.host_transfers == 0``).  Labels, rounds,
  and per-round stats are bitwise those of ``"host"`` mode.

``bfs_batch`` / ``sssp_batch`` serve B independent sources from ONE
shared convergence loop (DESIGN.md section 7): labels and frontier
carry a ``[B, V]`` batch axis, every balancer round plans over the
union frontier, and a finished query retires itself — its frontier row
empties, so it stops contributing vertices to the union while the loop
drains the remaining queries.  The loop ends when the union is empty,
and each query's labels are bitwise what its own single-source run
would have produced.

The continuous-batching service (``repro.serve``, DESIGN.md section 8)
builds on the same round structure through two public hooks here:
:func:`relax_round` (one balancer round in either execution mode) and
:func:`step_batch` (round + min-combine frontier update over ``[B, V]``
slot state), plus the :data:`QUERY_APPS` registry naming the
point-query applications a service can admit.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import Graph, INF
from ..frontier import full_frontier, single_source, multi_source_state
from ..balancer import (BalancerConfig, RoundStats, relax,
                        relax_spmd_directed, relax_fused_round,
                        run_fused, fused_stats_host,
                        host_transfer_count, _fused_stats_init,
                        _note_host_transfer)
from .. import operators as ops


@dataclasses.dataclass
class AppResult:
    """What every driver returns: final labels, round count, wall-clock
    seconds, (with ``collect_stats=True``) per-round
    :class:`RoundStats`, and the number of blocking device->host sync
    points the traversal's round loop performed (0 in fused mode —
    the assertable form of the zero-sync property, DESIGN.md
    section 11)."""
    labels: jax.Array
    rounds: int
    seconds: float
    stats: Optional[List[RoundStats]] = None
    host_transfers: int = 0


def relax_round(g, values, labels, frontier, cfg, op,
                collect_stats=False, mode="host",
                return_active=False):
    """One balancer round in the selected execution mode (``"host"`` |
    ``"spmd"``); always returns (labels, RoundStats|None) with
    host-side stats.  The single round primitive shared by every driver
    loop here and by the serving engine (DESIGN.md section 8).

    Both modes honour ``cfg.direction`` (DESIGN.md section 9): the
    host round resolves it inside :func:`repro.core.balancer.relax`,
    the spmd round through
    :func:`repro.core.balancer.relax_spmd_directed`.

    ``return_active=True`` appends a host ``bool[B]`` per-row liveness
    vector (``bool[1]`` un-batched) — in host mode it is sliced from
    the fused count transfer the round already pays, so the driver
    loops can converge without issuing a separate blocking
    ``jnp.any(frontier)`` every round."""
    if mode == "host":
        return relax(g, values, labels, frontier, cfg, op,
                     collect_stats=collect_stats,
                     return_active=return_active)
    if mode != "spmd":
        raise ValueError(f"unknown round mode {mode!r} (host|spmd — "
                         f"'fused' is a loop-level mode, not a "
                         f"single-round one)")
    return relax_spmd_directed(g, values, labels, frontier, cfg, op,
                               collect_stats=collect_stats,
                               return_active=return_active)


_round = relax_round                     # internal alias, kept short


def step_batch(g, labels, frontier, cfg, op, mode="host",
               collect_stats=False):
    """One serving step over ``[B, V]`` slot state: a balancer round
    followed by the min-combine frontier update (a vertex re-enters its
    query's worklist exactly when its label improved).  Returns
    ``(labels, next_frontier, RoundStats|None)``.

    This is the continuous-batching engine's inner loop body
    (DESIGN.md section 8): rows are independent, so the caller may
    retire/refill any subset of rows between steps — at fixed shapes,
    hence without recompiling — and every row still evolves bitwise
    like its standalone single-source run.  Only ``min``-combine
    operators (the point-query apps in :data:`QUERY_APPS`) are valid
    here."""
    if op.combine != "min":
        raise ValueError(f"step_batch serves min-combine point queries; "
                         f"got {op.name} (combine={op.combine!r})")
    old = labels
    labels, st = relax_round(g, labels, labels, frontier, cfg, op,
                             collect_stats=collect_stats, mode=mode)
    return labels, labels < old, st


# the point-query applications a serving deployment admits: name ->
# (operator, label fill value).  Initial state for a fresh query is
# multi_source_state / frontier.refill_rows with that fill.
QUERY_APPS = {
    "bfs": (ops.BFS_HOP, INF),
    "sssp": (ops.SSSP_RELAX, INF),
}


def resume_loop(g, labels, frontier, cfg, op, max_rounds: int = 10_000,
                collect_stats: bool = False, mode: str = "host",
                direction: Optional[str] = None) -> "AppResult":
    """Continue a min-combine data-driven loop from explicit
    labels/frontier state until the worklist drains.

    This is the incremental-repair entry point of the streaming layer
    (DESIGN.md section 10): ``repro.core.streaming.stream_update``
    seeds ``frontier`` from the endpoints of changed edges and resumes
    the ordinary round loop over the current labels — the exact loop
    :func:`bfs`/:func:`sssp`/:func:`cc` run, so every strategy,
    backend, execution mode, and traversal direction applies to repair
    rounds unchanged.  Only ``min``-combine operators are monotone
    under resumption (labels can only improve), so others are
    rejected."""
    if op.combine != "min":
        raise ValueError(f"resume_loop repairs min-combine fixpoints; "
                         f"got {op.name} (combine={op.combine!r})")
    cfg = _with_direction(cfg, direction)
    labels, rounds, secs, stats, syncs = _loop(
        g, lambda l: l, labels, frontier, cfg, op, max_rounds,
        collect_stats, next_frontier=lambda old, new, f: new < old,
        mode=mode)
    return AppResult(labels, rounds, secs, stats, syncs)


def _loop(g: Graph, values_of, labels, frontier, cfg, op,
          max_rounds: int, collect_stats: bool,
          next_frontier, post_round=None, mode: str = "host"):
    """Generic data-driven loop with explicit current/next worklists.

    In host/spmd mode, convergence is driven by the round's own
    ``return_active`` liveness (in host mode a slice of the fused count
    transfer the round already pays for) rather than a separate
    blocking ``jnp.any(frontier)``, so a host-mode round costs exactly
    ONE device->host transfer; an empty frontier is detected by the
    same probe, before any work launches.  ``mode="fused"`` hands the
    whole loop to :func:`repro.core.balancer.run_fused` instead — one
    ``lax.while_loop``, no per-round transfers at all.

    Returns ``(labels, rounds, seconds, stats, host_transfers)``;
    ``host_transfers`` is measured as the delta of the balancer's sync
    counter across the loop, so it is 0 for fused mode by construction
    *and* by observation.
    """
    t_sync = host_transfer_count()
    if mode == "fused":
        # fused mode fuses the min-combine `new < old` frontier update;
        # loops needing a post_round hook keep their own fused variant
        assert post_round is None
        t0 = time.perf_counter()
        labels, _, r, st_dev = run_fused(g, labels, frontier, cfg, op,
                                         max_rounds, collect_stats)
        jax.block_until_ready(labels)
        secs = time.perf_counter() - t0
        stats = fused_stats_host(st_dev, int(r)) if collect_stats else None
        return (labels, int(r), secs, stats,
                host_transfer_count() - t_sync)
    stats = [] if collect_stats else None
    t0 = time.perf_counter()
    rounds = 0
    while rounds < max_rounds:
        old = labels
        new, st, active = _round(g, values_of(labels), labels, frontier,
                                 cfg, op, collect_stats, mode,
                                 return_active=True)
        if not bool(np.any(active)):
            break                      # frontier empty: converged
        labels = new
        if post_round is not None:
            labels = post_round(labels)
        frontier = next_frontier(old, labels, frontier)
        if collect_stats and st is not None:
            stats.append(st)
        rounds += 1
    jax.block_until_ready(labels)
    return (labels, rounds, time.perf_counter() - t0, stats,
            host_transfer_count() - t_sync)


# ---------------------------------------------------------------------------

def _with_direction(cfg: BalancerConfig, direction) -> BalancerConfig:
    """Per-call ``direction=`` override of the strategy config
    (``push`` | ``pull`` | ``adaptive`` — DESIGN.md section 9); None
    keeps ``cfg.direction``.  The replaced config hashes by value, so
    overriding costs no extra jit traces."""
    if direction is None:
        return cfg
    return dataclasses.replace(cfg, direction=direction)


def sssp(g: Graph, source: int, cfg: BalancerConfig = BalancerConfig(),
         max_rounds: int = 10_000, collect_stats: bool = False,
         mode: str = "host", direction: Optional[str] = None) -> AppResult:
    """Bellman-Ford style data-driven SSSP (min-combine relaxation;
    ``direction`` selects push/pull/adaptive rounds per DESIGN.md
    section 9)."""
    cfg = _with_direction(cfg, direction)
    dist = jnp.full((g.num_vertices,), INF, dtype=jnp.int32).at[source].set(0)
    frontier = single_source(g.num_vertices, source)
    labels, rounds, secs, stats, syncs = _loop(
        g, lambda l: l, dist, frontier, cfg, ops.SSSP_RELAX, max_rounds,
        collect_stats, next_frontier=lambda old, new, f: new < old,
        mode=mode)
    return AppResult(labels, rounds, secs, stats, syncs)


def bfs(g: Graph, source: int, cfg: BalancerConfig = BalancerConfig(),
        max_rounds: int = 10_000, collect_stats: bool = False,
        mode: str = "host", direction: Optional[str] = None) -> AppResult:
    """Data-driven BFS: hop-count labels via min-combine rounds
    (``direction`` selects push/pull/adaptive per DESIGN.md
    section 9)."""
    cfg = _with_direction(cfg, direction)
    level = jnp.full((g.num_vertices,), INF, dtype=jnp.int32).at[source].set(0)
    frontier = single_source(g.num_vertices, source)
    labels, rounds, secs, stats, syncs = _loop(
        g, lambda l: l, level, frontier, cfg, ops.BFS_HOP, max_rounds,
        collect_stats, next_frontier=lambda old, new, f: new < old,
        mode=mode)
    return AppResult(labels, rounds, secs, stats, syncs)


# ---- batched multi-source queries (DESIGN.md section 7) -------------------

def _batch_loop(g: Graph, labels, frontier, cfg, op, max_rounds,
                collect_stats, mode) -> AppResult:
    """The shared multi-query convergence loop: identical round
    structure to :func:`_loop`, but over ``[B, V]`` state — each round
    is ONE balancer invocation serving the whole batch, and queries
    whose frontier row has emptied are retired implicitly (they no
    longer contribute to the union the round plans over)."""
    labels, rounds, secs, stats, syncs = _loop(
        g, lambda l: l, labels, frontier, cfg, op, max_rounds,
        collect_stats, next_frontier=lambda old, new, f: new < old,
        mode=mode)
    return AppResult(labels, rounds, secs, stats, syncs)


def sssp_batch(g: Graph, sources, cfg: BalancerConfig = BalancerConfig(),
               max_rounds: int = 10_000, collect_stats: bool = False,
               mode: str = "host",
               direction: Optional[str] = None) -> AppResult:
    """Batched multi-source SSSP: ``labels[b]`` equals (bitwise) the
    single-source :func:`sssp` labels for ``sources[b]``, computed by
    one union-frontier round loop for all B sources.  ``direction``
    selects push/pull/adaptive rounds (DESIGN.md section 9); the
    adaptive choice is made on the union frontier for the whole
    batch."""
    cfg = _with_direction(cfg, direction)
    labels, frontier = multi_source_state(g.num_vertices, sources, INF)
    return _batch_loop(g, labels, frontier, cfg, ops.SSSP_RELAX,
                       max_rounds, collect_stats, mode)


def bfs_batch(g: Graph, sources, cfg: BalancerConfig = BalancerConfig(),
              max_rounds: int = 10_000, collect_stats: bool = False,
              mode: str = "host",
              direction: Optional[str] = None) -> AppResult:
    """Batched multi-source BFS (see :func:`sssp_batch`)."""
    cfg = _with_direction(cfg, direction)
    labels, frontier = multi_source_state(g.num_vertices, sources, INF)
    return _batch_loop(g, labels, frontier, cfg, ops.BFS_HOP,
                       max_rounds, collect_stats, mode)


def cc(g: Graph, cfg: BalancerConfig = BalancerConfig(),
       max_rounds: int = 10_000, collect_stats: bool = False,
       mode: str = "host", direction: Optional[str] = None) -> AppResult:
    """Connected components by min-label propagation.

    Computes weakly-connected components when ``g`` is symmetrized
    (the benchmark harness symmetrizes, matching standard practice).
    ``direction`` selects push/pull/adaptive rounds (DESIGN.md
    section 9) — on the dense early frontiers of cc, adaptive rounds
    run as pulls.
    """
    cfg = _with_direction(cfg, direction)
    comp = jnp.arange(g.num_vertices, dtype=jnp.int32)
    frontier = full_frontier(g.num_vertices)
    labels, rounds, secs, stats, syncs = _loop(
        g, lambda l: l, comp, frontier, cfg, ops.CC_MIN, max_rounds,
        collect_stats, next_frontier=lambda old, new, f: new < old,
        mode=mode)
    return AppResult(labels, rounds, secs, stats, syncs)


@partial(jax.jit, static_argnames=("k", "cfg", "max_rounds",
                                   "collect_stats"))
def _kcore_fused(g: Graph, deg, frontier, dead_acc, k: int,
                 cfg: BalancerConfig, max_rounds: int,
                 collect_stats: bool):
    """kcore's whole peeling loop as ONE ``lax.while_loop`` (zero
    per-round host syncs): the balancer round is the device-resident
    :func:`repro.core.balancer.relax_fused_round`, and the
    newly-dead bookkeeping — the host loop's ``post_round`` logic —
    moves into the loop body unchanged."""
    st0 = (_fused_stats_init(max_rounds, 1, cfg.num_tiles)
           if collect_stats else None)

    def cond(carry):
        r, deg, dead, fr, st = carry
        return (r < max_rounds) & jnp.any(fr)

    def body(carry):
        r, deg, dead, fr, st = carry
        new_deg, _, _, _, row = relax_fused_round(
            g, None, None, deg[None], deg[None], fr[None], cfg,
            ops.KCORE_DEC, None, collect_stats)
        new_deg = new_deg[0]
        newly_dead = (new_deg < k) & ~dead
        if collect_stats:
            st = jax.tree_util.tree_map(
                lambda buf, x: buf.at[r].set(x), st, row)
        return r + 1, new_deg, dead | newly_dead, newly_dead, st

    r, deg, dead, fr, st = jax.lax.while_loop(
        cond, body, (jnp.int32(0), deg, dead_acc, frontier, st0))
    return (~dead).astype(jnp.int32), r, st


def kcore(g: Graph, k: int, cfg: BalancerConfig = BalancerConfig(),
          max_rounds: int = 10_000, collect_stats: bool = False,
          mode: str = "host") -> AppResult:
    """k-core decomposition: labels[v] = 1 if v is in the k-core.

    Push formulation: when a vertex dies its neighbours lose one degree
    (the paper uses the pull variant; the fixpoint is identical).
    Expects a symmetrized graph.
    """
    deg = g.out_degrees().astype(jnp.int32)
    alive = deg >= k
    frontier = ~alive & (deg > 0)          # initially-dead vertices push
    dead_acc = frontier | ~alive
    if mode == "fused":
        # validate direction x operator exactly like the per-round modes
        if cfg.direction != "push":
            ops.as_pull(ops.KCORE_DEC)     # raises: add-combine op
        t_sync = host_transfer_count()
        t0 = time.perf_counter()
        in_core, r, st_dev = _kcore_fused(g, deg, frontier, dead_acc,
                                          int(k), cfg, max_rounds,
                                          collect_stats)
        jax.block_until_ready(in_core)
        secs = time.perf_counter() - t0
        stats = fused_stats_host(st_dev, int(r)) if collect_stats else None
        return AppResult(in_core, int(r), secs, stats,
                         host_transfer_count() - t_sync)
    stats = [] if collect_stats else None
    t_sync = host_transfer_count()
    t0 = time.perf_counter()
    rounds = 0
    while rounds < max_rounds:
        new_deg, st, active = _round(g, deg, deg, frontier, cfg,
                                     ops.KCORE_DEC, collect_stats, mode,
                                     return_active=True)
        if not bool(np.any(active)):
            break                      # no vertex died last round
        deg = new_deg
        newly_dead = (deg < k) & ~dead_acc
        dead_acc = dead_acc | newly_dead
        frontier = newly_dead
        if collect_stats and st is not None:
            stats.append(st)
        rounds += 1
    jax.block_until_ready(deg)
    in_core = (~dead_acc).astype(jnp.int32)
    return AppResult(in_core, rounds, time.perf_counter() - t0, stats,
                     host_transfer_count() - t_sync)


@partial(jax.jit, static_argnames=("damping",))
def _pr_round_math(rank, inv_out, sink, acc, damping: float):
    """The scalar arithmetic around PageRank's relax round, shared by
    the host loop and the fused while_loop so both take the SAME fusion
    decisions (an enclosing jit would otherwise contract the update
    into an FMA and perturb the last f32 bit).  Called with ``acc=None``
    for the pre-round pieces, with the scattered ``acc`` for the
    post-round update + residual."""
    n = rank.shape[0]
    if acc is None:
        contrib = rank * inv_out
        dangling = jnp.sum(jnp.where(sink, rank, 0.0))
        return contrib, dangling
    dangling = jnp.sum(jnp.where(sink, rank, 0.0))
    new_rank = (1.0 - damping) / n + damping * (acc + dangling / n)
    delta = jnp.max(jnp.abs(new_rank - rank))
    return new_rank, delta


@partial(jax.jit, static_argnames=("damping", "tol", "cfg",
                                   "max_rounds", "collect_stats"))
def _pagerank_fused(rg: Graph, inv_out, sink, damping: float,
                    tol: float, cfg: BalancerConfig, max_rounds: int,
                    collect_stats: bool):
    """PageRank's whole power iteration as ONE ``lax.while_loop``:
    the residual check that used to block the host every round
    (``float(jnp.max(...))``) becomes part of the loop condition on
    device.  The per-round arithmetic goes through ``_pr_round_math``
    — the same jitted subgraph the host loop calls — so f32 rounding
    is bitwise-identical between the two modes."""
    n = inv_out.shape[0]
    rank0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    frontier = full_frontier(n)
    st0 = (_fused_stats_init(max_rounds, 1, cfg.num_tiles)
           if collect_stats else None)

    def cond(carry):
        r, rank, delta, st = carry
        return (r < max_rounds) & (delta >= tol)

    def body(carry):
        r, rank, delta, st = carry
        contrib, _ = _pr_round_math(rank, inv_out, sink, None, damping)
        acc = jnp.zeros((n,), jnp.float32)
        # pull: gather contrib at in-neighbours, scatter-add at anchor
        acc, _, _, _, row = relax_fused_round(
            rg, None, None, contrib[None], acc[None], frontier[None],
            cfg, ops.PR_PULL, None, collect_stats)
        acc = acc[0]
        new_rank, delta = _pr_round_math(rank, inv_out, sink, acc,
                                         damping)
        if collect_stats:
            st = jax.tree_util.tree_map(
                lambda buf, x: buf.at[r].set(x), st, row)
        return r + 1, new_rank, delta, st

    r, rank, _, st = jax.lax.while_loop(
        cond, body, (jnp.int32(0), rank0, jnp.float32(jnp.inf), st0))
    return rank, r, st


def pagerank(g: Graph, damping: float = 0.85, tol: float = 1e-6,
             cfg: BalancerConfig = BalancerConfig(),
             max_rounds: int = 1000, collect_stats: bool = False,
             rg: Graph | None = None, mode: str = "host") -> AppResult:
    """Pull-style topology-driven PageRank (residual tolerance).

    Dangling vertices (out-degree 0) redistribute their rank mass
    uniformly each round, so ``sum(rank) == 1`` is preserved on graphs
    with sinks — without this, sinks leak mass every round, ranks
    deflate, and ``tol`` is checked against shrunken values."""
    n = g.num_vertices
    if rg is None:
        rg = g.reverse()                   # pull traverses in-edges
    outdeg = g.out_degrees().astype(jnp.float32)
    inv_out = jnp.where(outdeg > 0, 1.0 / jnp.maximum(outdeg, 1.0), 0.0)
    sink = outdeg == 0
    if mode == "fused":
        if cfg.direction != "push":
            ops.as_pull(ops.PR_PULL)       # raises: not a push-min op
        t_sync = host_transfer_count()
        t0 = time.perf_counter()
        rank, r, st_dev = _pagerank_fused(rg, inv_out, sink,
                                          float(damping), float(tol),
                                          cfg, max_rounds,
                                          collect_stats)
        jax.block_until_ready(rank)
        secs = time.perf_counter() - t0
        stats = fused_stats_host(st_dev, int(r)) if collect_stats else None
        return AppResult(rank, int(r), secs, stats,
                         host_transfer_count() - t_sync)
    rank = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    frontier = full_frontier(n)
    stats = [] if collect_stats else None
    t_sync = host_transfer_count()
    t0 = time.perf_counter()
    rounds = 0
    while rounds < max_rounds:
        contrib, _ = _pr_round_math(rank, inv_out, sink, None,
                                    float(damping))
        acc = jnp.zeros((n,), jnp.float32)
        # pull: gather contrib at in-neighbours, scatter-add at anchor
        acc, st = _round(rg, contrib, acc, frontier, cfg, ops.PR_PULL,
                         collect_stats, mode)
        new_rank, delta_dev = _pr_round_math(rank, inv_out, sink, acc,
                                             float(damping))
        delta = float(delta_dev)
        _note_host_transfer()          # the residual check blocks
        rank = new_rank
        if collect_stats and st is not None:
            stats.append(st)
        rounds += 1
        if delta < tol:
            break
    jax.block_until_ready(rank)
    return AppResult(rank, rounds, time.perf_counter() - t0, stats,
                     host_transfer_count() - t_sync)
