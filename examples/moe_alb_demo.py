"""The paper's technique inside the LM stack: ALB-adaptive MoE dispatch.

Skewed inputs make the router send nearly all tokens to two experts
(the power-law situation).  The static (blocked) dispatch drops the
overflow; the ALB executor re-deals overflow slots cyclically across
the free capacity of ALL experts via the same prefix-sum + searchsorted
renumbering the graph LB kernel uses.

  PYTHONPATH=src python examples/moe_alb_demo.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses                                  # noqa: E402
import jax                                          # noqa: E402
import jax.numpy as jnp                             # noqa: E402
import numpy as np                                  # noqa: E402

from repro.configs.base import ModelConfig, MoEConfig  # noqa: E402
from repro.models import moe as MOE                 # noqa: E402


def cfg_with(adaptive):
    return ModelConfig(
        name="demo", family="moe", num_layers=1, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=8, top_k=2, num_shared_experts=0,
                      d_expert=32, capacity_factor=1.0,
                      adaptive=adaptive))


key = jax.random.PRNGKey(0)
cfg = cfg_with(True)
params = MOE.moe_init(key, cfg)

# skewed tokens: nearly identical -> router sends everyone to the same
# two experts
base = jax.random.normal(jax.random.PRNGKey(2), (1, 1, cfg.d_model))
x = base + 0.01 * jax.random.normal(jax.random.PRNGKey(3),
                                    (8, 64, cfg.d_model))

for adaptive in [False, True]:
    c = cfg_with(adaptive)
    t = x.shape[0] * x.shape[1]
    xf = x.reshape(t, -1)
    probs = jax.nn.softmax(
        (xf @ params["router"]).astype(jnp.float32), axis=-1)
    flat_e, pos, gate, keep, cap = MOE.dispatch_plan(probs, c.moe, t)
    load = np.bincount(np.asarray(flat_e)[np.asarray(keep)],
                       minlength=8)
    kept = float(jnp.mean(keep.astype(jnp.float32)))
    name = "ALB (adaptive)" if adaptive else "static (blocked)"
    print(f"{name:18s}: kept {kept * 100:5.1f}% of token-slots; "
          f"per-expert load = {load.tolist()} (cap={cap})")

print("\nALB inspector-executor: identical machinery to the paper's LB "
      "kernel\n(exclusive prefix sum over free slots + searchsorted "
      "re-deal).")
