"""Service-level instrumentation.

Where :class:`repro.core.balancer.RoundStats` measures one balancer
round, :class:`ServiceStats` measures the *service*: how many queries
were served (and how many straight from cache), the distribution of
rounds-in-system (queue wait + slot residency, the service's latency
in its natural unit), and how full the slot array ran (occupancy = the
fraction of slot-rounds that held a query — the utilization that
continuous batching exists to maximize, DESIGN.md section 8).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

# EWMA smoothing factor for the rounds-remaining estimate the fleet
# router scores replicas by (DESIGN.md section 13)
EWMA_ALPHA = 0.25


@dataclasses.dataclass
class ServiceStats:
    """Counters accumulated by a :class:`repro.serve.QueryService`."""
    queries_served: int = 0        # completed, including cache hits
    cache_hits: int = 0            # served with NO device work: LRU
    #                                hits + single-flight coalesced
    cache_misses: int = 0          # actually computed on the device
    steps: int = 0                 # service rounds executed
    slot_rounds_total: int = 0     # B per step (the capacity offered)
    slot_rounds_busy: int = 0      # ... of which held a RUNNING query
    preemptions: int = 0
    cancellations: int = 0         # queries withdrawn before completion
    #                                (the fleet's hedge losers)
    host_transfers: int = 0        # device->host syncs during stepping
    #                                (balancer round counts + liveness
    #                                probes; fused mode amortizes them
    #                                over whole chunks of rounds)
    ewma_rounds: float = 0.0       # EWMA of rounds-in-system over
    #                                COMPUTED completions — the
    #                                rounds-remaining estimate the
    #                                fleet router's tail-risk score
    #                                consumes (DESIGN.md section 13)
    queue_head_age: int = 0        # steps the oldest pending query has
    #                                waited (refreshed every step; 0
    #                                when the queue is empty)
    rounds_in_system: List[int] = dataclasses.field(default_factory=list)

    def record_step(self, busy: int, total: int) -> None:
        """Account one service round offering ``total`` slot-rounds of
        which ``busy`` were occupied."""
        self.steps += 1
        self.slot_rounds_total += total
        self.slot_rounds_busy += busy

    def record_done(self, rounds_in_system: int,
                    from_cache: bool) -> None:
        """Account one completed query.  Computed (non-cache)
        completions also advance ``ewma_rounds``, the rounds-remaining
        estimate served to the fleet router — cache hits are excluded
        because their 0 rounds say nothing about the cost of the work
        still in the system."""
        self.queries_served += 1
        if from_cache:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
            r = float(rounds_in_system)
            self.ewma_rounds = (
                r if self.cache_misses == 1
                else (1.0 - EWMA_ALPHA) * self.ewma_rounds
                + EWMA_ALPHA * r)
        self.rounds_in_system.append(int(rounds_in_system))

    @property
    def occupancy(self) -> float:
        """Busy slot-rounds / offered slot-rounds (0.0 before any
        step)."""
        if self.slot_rounds_total == 0:
            return 0.0
        return self.slot_rounds_busy / self.slot_rounds_total

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of completed queries answered from the cache."""
        if self.queries_served == 0:
            return 0.0
        return self.cache_hits / self.queries_served

    def latency_percentile(self, p: float) -> float:
        """p-th percentile of rounds-in-system over completed queries.

        Empty and single-sample windows are well-defined sentinels —
        ``0.0`` before any completion, the sample itself after one —
        never NaN: the fleet layer aggregates per-replica percentiles
        into its feedback controller (DESIGN.md section 13), and a
        just-started replica must read as "no observed latency", not
        poison every mean/comparison it joins."""
        if not self.rounds_in_system:
            return 0.0
        return float(np.percentile(np.asarray(self.rounds_in_system), p))

    def summary(self) -> dict:
        """One flat dict for logging/benchmark emission."""
        return {
            "queries_served": self.queries_served,
            "cache_hit_rate": round(self.cache_hit_rate, 4),
            "steps": self.steps,
            "occupancy": round(self.occupancy, 4),
            "preemptions": self.preemptions,
            "cancellations": self.cancellations,
            "host_transfers": self.host_transfers,
            "ewma_rounds": round(self.ewma_rounds, 3),
            "lat_rounds_p50": self.latency_percentile(50),
            "lat_rounds_p95": self.latency_percentile(95),
        }
