"""The continuous-batching query service (DESIGN.md section 8).

:class:`QueryService` runs the ALB round loop as a *service*: queries
arrive continuously via ``submit``, each occupies one row (a **slot**)
of a ``[B, V]`` slot bank, and the bank advances one balancer round
per ``step``.  A row whose frontier empties has converged — it is
retired and its slot refilled from the queue *mid-loop*, at fixed
``[B, V]`` shapes, so admission never recompiles or restarts the loop.
Because batch rows are independent (inactive rows scatter only the
combiner's identity), every served query is bitwise equal to its
standalone ``bfs``/``sssp`` run regardless of what shared its batch.

Composition (one class per module in this package):

* :class:`repro.serve.queue.QueryQueue` — submit/poll bookkeeping,
  FIFO pending order;
* :class:`repro.serve.scheduler.Scheduler` — deterministic admission +
  round-budget preemption (snapshot/resume, exact);
* :class:`repro.serve.cache.ResultCache` — LRU over
  (graph_id, app, source, strategy), invalidated per graph on
  re-registration; the same key drives single-flight coalescing of
  identical in-flight submissions;
* :class:`repro.serve.stats.ServiceStats` — queries served, p50/p95
  rounds-in-system, slot occupancy, cache hit rate.

Slot banks are keyed ``(graph_id, app)`` — a balancer round applies
one operator to its whole batch — and created lazily on first demand.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, INF
from repro.core.balancer import (BalancerConfig, run_fused,
                                 host_transfer_count,
                                 _note_host_transfer)
from repro.core.frontier import rows_active, refill_rows, load_rows
from repro.core.apps.drivers import QUERY_APPS, step_batch
from repro.core.streaming import UpdateBatch, apply_updates, diff_batch

from .queue import (Query, QueryQueue, QUEUED, RUNNING, DONE,
                    CANCELLED)
from .scheduler import Scheduler, SlotView, Decision
from .cache import ResultCache
from .publish import freeze
from .stats import ServiceStats


class _SlotBank:
    """Device state of one (graph_id, app) batch: ``[B, V]`` labels +
    frontier, plus the host-side slot -> query map.

    ``stale=True`` marks a bank pinned to a superseded graph version
    (DESIGN.md section 10): it admits and preempts nothing, its
    occupants drain to completion against the pre-update snapshot it
    holds in ``self.g``, and the engine deletes it once empty."""

    def __init__(self, g: Graph, app: str, num_slots: int) -> None:
        self.g = g
        self.app = app
        self.op, self.fill = QUERY_APPS[app]
        self.stale = False
        v = g.num_vertices
        self.labels = jnp.full((num_slots, v), self.fill, jnp.int32)
        self.frontier = jnp.zeros((num_slots, v), dtype=bool)
        self.slot_q: list = [None] * num_slots      # Query | None

    @property
    def num_slots(self) -> int:
        return len(self.slot_q)

    def views(self) -> list:
        """Scheduler-facing occupancy views, ascending slot order."""
        return [SlotView(slot=s,
                         qid=None if q is None else q.qid,
                         slot_rounds=0 if q is None else q.slot_rounds)
                for s, q in enumerate(self.slot_q)]

    def busy(self) -> int:
        return sum(q is not None for q in self.slot_q)


class QueryService:
    """Continuous-batching BFS/SSSP service over registered graphs.

    ``num_slots`` fixes B (per slot bank); ``cfg``/``mode`` select the
    balancer strategy and round implementation for every bank —
    including the traversal direction (``cfg.direction``, DESIGN.md
    section 9), which therefore also joins the result-cache key: A/B
    deployments of push vs adaptive configs never share entries;
    ``round_budget`` enables preemptive fairness (see
    :class:`repro.serve.scheduler.Scheduler`); ``cache_capacity``
    bounds the LRU result cache (0 disables it).

    ``mode="fused"`` advances each bank by a device-resident CHUNK of
    up to ``fused_rounds`` balancer rounds per service step (one
    ``lax.while_loop`` dispatch, DESIGN.md section 11): admission,
    retirement, and preemption then happen at chunk granularity, while
    every served result stays bitwise equal to host mode (fused rounds
    are the same SPMD rounds).  ``ServiceStats.host_transfers`` makes
    the amortization observable — one fused observation per step
    instead of one blocking sync per round.

    Typical use::

        svc = QueryService(num_slots=8)
        svc.register_graph("social", g)
        qid = svc.submit("social", "bfs", source=17)
        svc.run()                       # drain queue + slots
        labels = svc.poll(qid).result   # np.ndarray[V], bitwise ==
                                        # apps.bfs(g, 17).labels
    """

    def __init__(self, num_slots: int = 8,
                 cfg: BalancerConfig = BalancerConfig(),
                 mode: str = "host",
                 round_budget: Optional[int] = None,
                 cache_capacity: int = 256,
                 fused_rounds: int = 8) -> None:
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if mode == "fused" and fused_rounds < 1:
            raise ValueError("fused_rounds must be >= 1")
        self.num_slots = num_slots
        self.cfg = cfg
        self.mode = mode
        self.fused_rounds = fused_rounds
        self.queue = QueryQueue()
        self.scheduler = Scheduler(round_budget=round_budget)
        self.cache = ResultCache(capacity=cache_capacity)
        self.stats = ServiceStats()
        self._graphs: Dict[str, Graph] = {}
        self._banks: Dict[tuple, _SlotBank] = {}
        self._step = 0
        # single-flight coalescing: cache-key -> primary qid of the
        # in-flight computation identical submissions attach to
        self._inflight: Dict[tuple, int] = {}
        self._followers: Dict[int, list] = {}
        # (step, qid, slot) admission trace — the determinism witness
        self.admission_log: list = []

    # ---- graph registry --------------------------------------------------

    def register_graph(self, graph_id: str, g: Graph) -> None:
        """Bind ``graph_id`` to a CSR graph.  Re-registering an id
        invalidates its cache entries (the binding changed) and drops
        its idle slot banks; it is an error while queries for the id
        are still in flight."""
        if graph_id in self._graphs:
            if self.queue.in_flight(graph_id):
                raise ValueError(
                    f"cannot re-register {graph_id!r}: queries in flight")
            self.cache.invalidate_graph(graph_id)
            for key in [k for k in self._banks if k[0] == graph_id]:
                del self._banks[key]
        self._graphs[graph_id] = g

    def apply_updates(self, graph_id: str, batch: UpdateBatch) -> int:
        """Mutate a registered graph with a streaming
        :class:`~repro.core.streaming.UpdateBatch` (DESIGN.md
        section 10), WITHOUT quiescing the service.  Returns how many
        cache entries the update evicted.

        Unlike :meth:`register_graph`, this is legal while queries are
        in flight — the binding advances *functionally*:

        * the new CSR (same shapes, version + 1) replaces the binding
          for all FUTURE admissions;
        * busy slot banks keep their pre-update ``Graph`` snapshot and
          are marked stale: they stop admitting and preempting, drain
          their occupants against the topology those queries were
          submitted under, and are deleted once empty (queued work for
          the bank then admits into a fresh bank on the new version);
        * cache eviction is fine-grained: only entries whose
          reachability tag intersects the update's changed-edge
          sources are dropped (:meth:`ResultCache.invalidate_delta`),
          so untouched regions keep their hit rate across the bump;
        * single-flight coalescing keys on the graph version, so a
          post-update submitter never attaches to (or is answered by)
          a pre-update in-flight computation.
        """
        if graph_id not in self._graphs:
            raise ValueError(f"unknown graph {graph_id!r}")
        g = self._graphs[graph_id]
        delta = diff_batch(g, batch)
        self._graphs[graph_id] = apply_updates(g, batch, in_place=False)
        evicted = self.cache.invalidate_delta(graph_id, delta.sources())
        for key in [k for k in self._banks if k[0] == graph_id]:
            bank = self._banks[key]
            if bank.busy():
                bank.stale = True
            else:
                del self._banks[key]
        return evicted

    # ---- submit / poll ---------------------------------------------------

    def submit(self, graph_id: str, app: str, source: int) -> int:
        """Enqueue one point query; returns its qid.

        Two short-circuits keep repeat traffic off the device: a
        **cache hit** is answered immediately (status DONE,
        ``from_cache=True``, rounds-in-system 0), and a submission
        identical to one still in flight is **coalesced** onto it
        (single-flight): it never occupies a slot, and completes —
        also marked ``from_cache`` — the moment its primary does."""
        if graph_id not in self._graphs:
            raise ValueError(f"unknown graph {graph_id!r}")
        if app not in QUERY_APPS:
            raise ValueError(
                f"unknown app {app!r} (have {sorted(QUERY_APPS)})")
        g = self._graphs[graph_id]
        if not 0 <= int(source) < g.num_vertices:
            raise ValueError(f"source {source} out of range "
                             f"[0, {g.num_vertices})")
        cached = self.cache.get(graph_id, app, source, self.cfg)
        # single-flight keys include the graph VERSION (DESIGN.md
        # section 10): a submission after apply_updates never coalesces
        # onto a computation still draining against the old topology
        key = self.cache.key(graph_id, app, source, self.cfg) \
            + (g.version,)
        primary = None if cached is not None else self._inflight.get(key)
        q = self.queue.submit(
            graph_id, app, source, step=self._step,
            enqueue=cached is None and primary is None)
        q.version = g.version
        q.inflight_key = key
        if cached is not None:
            self._finish(q, cached, from_cache=True)
        elif primary is not None:
            self._followers.setdefault(primary, []).append(q)
        else:
            self._inflight[key] = q.qid
        return q.qid

    def poll(self, qid: int) -> Query:
        """The query's live record: ``status``
        (queued/running/done/cancelled), ``result`` (host labels once
        done), ``rounds_in_system``, ``from_cache``."""
        return self.queue.poll(qid)

    def cancel(self, qid: int) -> bool:
        """Withdraw a query before completion (DESIGN.md section 13:
        the fleet cancels the losing finisher of a hedged pair).
        Returns True when the query was cancelled, False when it had
        already completed — its result stands, and the caller (the
        fleet's publication point) is responsible for dropping it.

        A QUEUED query leaves the pending FIFO (a coalesced follower
        is instead detached from its primary); a RUNNING query's slot
        is cleared on device (labels reset to fill, frontier row
        zeroed — a fixed-shape ``load_rows`` scatter, so cancellation
        never recompiles the loop).  A cancelled *primary* promotes
        its first follower into the pending FIFO so coalesced
        submitters are still answered."""
        q = self.queue.poll(qid)
        if q.status in (DONE, CANCELLED):
            return False
        if q.status == QUEUED:
            try:
                self.queue.remove_pending(qid)
            except ValueError:
                # single-flight follower: never enqueued — detach it
                # from its primary's fan-out list
                primary = self._inflight.get(q.inflight_key)
                fs = self._followers.get(primary, [])
                if q in fs:
                    fs.remove(q)
        else:                                      # RUNNING
            bank = self._banks[(q.graph_id, q.app)]
            b, v = bank.num_slots, bank.g.num_vertices
            slots = np.full((b,), b, np.int32)
            slots[0] = q.slot
            bank.labels, bank.frontier = load_rows(
                bank.labels, bank.frontier, slots,
                np.full((b, v), bank.fill, np.int32),
                np.zeros((b, v), bool))
            bank.slot_q[q.slot] = None
            if bank.stale and not bank.busy():
                del self._banks[(q.graph_id, q.app)]
        # release the single-flight registration; a waiting follower
        # is promoted to a real pending computation
        key = q.inflight_key
        if key is not None and self._inflight.get(key) == q.qid:
            del self._inflight[key]
            followers = self._followers.pop(q.qid, [])
            if followers:
                heir = followers[0]
                self.queue.enqueue_existing(heir)
                self._inflight[key] = heir.qid
                if len(followers) > 1:
                    self._followers[heir.qid] = followers[1:]
        q.status = CANCELLED
        q.slot = None
        q.saved_state = None
        q.done_step = self._step
        self.stats.cancellations += 1
        return True

    # ---- fleet-facing load signals (DESIGN.md section 13) ----------------

    def load(self) -> int:
        """Assigned load: queries currently QUEUED or RUNNING — the
        quantity the fleet router's bounded-load rule budgets."""
        return self.queue.active_count()

    def queue_head_age(self) -> int:
        """Service steps the oldest pending query has waited (0 when
        nothing is pending) — the head-of-line-blocking term of the
        fleet router's tail-risk score."""
        head = self.queue.head_submit_step()
        return 0 if head is None else self._step - head

    def rounds_remaining(self) -> float:
        """Estimated balancer rounds of work still in this service:
        for each RUNNING query, the EWMA of completed rounds-in-system
        minus the rounds it has already run (floored at 1 — an
        admitted query always costs at least its current round), plus
        one full EWMA per pending query.  This is the
        ``work_remaining`` term of the fleet router's tail-risk score
        (DESIGN.md section 13); 0.0 on an idle, just-started
        replica."""
        ewma = self.stats.ewma_rounds
        rem = 0.0
        for bank in self._banks.values():
            for q in bank.slot_q:
                if q is not None:
                    rem += max(ewma - q.slot_rounds, 1.0)
        rem += len(self.queue) * max(ewma, 1.0)
        return rem

    # ---- the serving loop ------------------------------------------------

    def step(self) -> bool:
        """One service round: for every slot bank with work — admit
        (after any preemptions), run one balancer round, retire
        converged slots.  Returns False when nothing was left to do
        (queue empty, all slots idle)."""
        self._step += 1
        self.stats.queue_head_age = self.queue_head_age()
        did_work = False
        for key in self._bank_keys_with_work():
            did_work |= self._step_bank(key)
        return did_work

    def run(self, max_steps: int = 1_000_000) -> ServiceStats:
        """Drain: step until every submitted query is DONE (bounded by
        ``max_steps`` as a divergence guard).  Returns the accumulated
        :class:`ServiceStats`."""
        for _ in range(max_steps):
            if not self.step():
                return self.stats
        raise RuntimeError(f"service did not drain in {max_steps} steps")

    # ---- internals -------------------------------------------------------

    def _bank_keys_with_work(self) -> list:
        keys = list(self._banks)    # insertion order: deterministic
        keys = [k for k in keys if self._banks[k].busy()
                or self.queue.pending_count(*k)]
        for k in self.queue.banks_with_pending():
            if k not in keys:
                keys.append(k)
        return keys

    def _bank(self, key: tuple) -> _SlotBank:
        if key not in self._banks:
            graph_id, app = key
            self._banks[key] = _SlotBank(self._graphs[graph_id], app,
                                         self.num_slots)
        return self._banks[key]

    def _finish(self, q: Query, labels: np.ndarray,
                from_cache: bool) -> None:
        """Complete a query and fan its labels out to any coalesced
        followers.  The ndarray is SHARED — one object between the LRU
        entry, this query's ``poll().result`` and every follower's — so
        it is frozen here (:func:`repro.serve.publish.freeze`): a
        caller mutating a result raises instead of silently corrupting
        every future cache hit."""
        labels = freeze(labels)
        q.status = DONE
        q.result = labels
        q.from_cache = from_cache
        q.done_step = self._step
        q.slot = None
        q.saved_state = None
        self.stats.record_done(q.rounds_in_system, from_cache)
        key = q.inflight_key
        if key is not None and self._inflight.get(key) == q.qid:
            del self._inflight[key]
        for f in self._followers.pop(q.qid, ()):
            self._finish(f, labels, from_cache=True)

    def _step_bank(self, key: tuple) -> bool:
        bank = self._bank(key)
        graph_id, app = key
        b = bank.num_slots

        # 1. plan admissions/preemptions against current occupancy.
        #    A stale bank (superseded graph version) plans NOTHING: no
        #    admissions — queued work waits for a fresh bank on the new
        #    version — and no preemptions, so its occupants run to
        #    completion on the snapshot they started on.
        if bank.stale:
            decision = Decision(preempt=(), admit=())
        else:
            decision = self.scheduler.plan(
                bank.views(), self.queue.pending_count(graph_id, app))

        # 2. preempt: snapshot rows to host, requeue at the back
        #    (whole-array device_get — cheaper to dispatch than a
        #    fancy-index row gather, and preemption steps are rare)
        if decision.preempt:
            l_host = np.asarray(bank.labels)
            f_host = np.asarray(bank.frontier)
            for slot in decision.preempt:
                q = bank.slot_q[slot]
                q.saved_state = (l_host[slot].copy(),
                                 f_host[slot].copy())
                q.preemptions += 1
                self.stats.preemptions += 1
                self.queue.requeue(q)
                bank.slot_q[slot] = None

        # 3. admit: fresh queries reset their row, resumed queries
        #    restore their snapshot — one fixed-K scatter each, so the
        #    loop shapes never change
        fresh, resumed = [], []
        for slot in decision.admit:
            q = self.queue.next_pending(graph_id, app)
            if q is None:
                break
            q.status = RUNNING
            q.slot = slot
            q.slot_rounds = 0
            if q.version != bank.g.version:
                # the graph mutated while this query queued: rebind it
                # to the version this bank actually computes against —
                # re-key its single-flight registration and drop any
                # preemption snapshot (taken on the old topology)
                if (q.inflight_key is not None and
                        self._inflight.get(q.inflight_key) == q.qid):
                    del self._inflight[q.inflight_key]
                q.version = bank.g.version
                if q.inflight_key is not None:
                    q.inflight_key = (q.inflight_key[:-1]
                                      + (bank.g.version,))
                    self._inflight.setdefault(q.inflight_key, q.qid)
                q.saved_state = None
            bank.slot_q[slot] = q
            self.admission_log.append((self._step, q.qid, slot))
            (resumed if q.saved_state is not None else fresh).append(
                (slot, q))
        if fresh:
            slots = np.full((b,), b, np.int32)
            srcs = np.zeros((b,), np.int32)
            for i, (slot, q) in enumerate(fresh):
                slots[i], srcs[i] = slot, q.source
            bank.labels, bank.frontier = refill_rows(
                bank.labels, bank.frontier, slots, srcs, bank.fill)
        if resumed:
            slots = np.full((b,), b, np.int32)
            v = bank.g.num_vertices
            lrows = np.zeros((b, v), np.int32)
            frows = np.zeros((b, v), bool)
            for i, (slot, q) in enumerate(resumed):
                slots[i] = slot
                lrows[i], frows[i] = q.saved_state
                q.saved_state = None
            bank.labels, bank.frontier = load_rows(
                bank.labels, bank.frontier, slots, lrows, frows)

        busy = bank.busy()
        if busy == 0:
            return False

        # 4. one balancer round for the whole bank — or, in fused
        #    mode, a CHUNK of up to ``fused_rounds`` rounds as ONE
        #    device dispatch (DESIGN.md section 11): the chunk's round
        #    loop runs with zero host syncs, and the per-step
        #    observation below amortizes over the whole chunk.
        t_sync = host_transfer_count()
        if self.mode == "fused":
            bank.labels, bank.frontier, r_dev, _ = run_fused(
                bank.g, bank.labels, bank.frontier, self.cfg, bank.op,
                max_rounds=self.fused_rounds)
        else:
            bank.labels, bank.frontier, _ = step_batch(
                bank.g, bank.labels, bank.frontier, self.cfg, bank.op,
                mode=self.mode)
            r_dev = 1
        self.stats.record_step(busy=busy, total=b)

        # 5. retire: occupied rows whose frontier emptied have
        #    converged — publish, cache, free the slot.  The steady
        #    per-step transfer is only the chunk's round count plus the
        #    ``bool[B]`` liveness vector (ONE fused fetch); the [B, V]
        #    labels are fetched (one dense device_get — cheaper to
        #    dispatch than per-row gathers) only on steps where
        #    something actually retired.
        rounds_ran, act = jax.device_get(
            (r_dev, rows_active(bank.frontier)))
        _note_host_transfer()
        rounds_ran = int(rounds_ran)
        for q in bank.slot_q:
            if q is not None:
                q.slot_rounds += rounds_ran
        self.stats.host_transfers += host_transfer_count() - t_sync
        done = [slot for slot, q in enumerate(bank.slot_q)
                if q is not None and not act[slot]]
        if done:
            l_host = np.asarray(bank.labels)
            cur = self._graphs.get(graph_id)
            for slot in done:
                q = bank.slot_q[slot]
                labels = l_host[slot].copy()
                # cache only results for the CURRENT graph version (a
                # stale bank's drain products answer their submitters
                # but must not poison future hits), tagged with the
                # query's reachable region so streaming updates can
                # evict at delta granularity (DESIGN.md section 10)
                if cur is not None and q.version == cur.version:
                    self.cache.put(graph_id, app, q.source, self.cfg,
                                   labels, region=labels < INF)
                self._finish(q, labels, from_cache=False)
                bank.slot_q[slot] = None
        if bank.stale and not bank.busy():
            del self._banks[key]
        return True
