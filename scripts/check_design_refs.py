#!/usr/bin/env python3
"""Docs-link invariant: every ``DESIGN.md section N`` reference in the
tree must resolve to a ``Section N`` heading in DESIGN.md.

Run from anywhere:  python scripts/check_design_refs.py
Exit status 0 = all references resolve; 1 = missing DESIGN.md or at
least one dangling reference (offenders listed on stderr).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REF = re.compile(r"DESIGN\.md\s+section\s+(\d+)", re.IGNORECASE)
HEADING = re.compile(r"^#{1,6}\s+Section\s+(\d+)\b", re.MULTILINE)
SCAN_SUFFIXES = {".py", ".md", ".txt", ".yml", ".yaml"}
SKIP_PARTS = {".git", "__pycache__", ".github", ".venv", "venv",
              "node_modules", ".claude", ".tox", ".eggs"}


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    design = root / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist but the tree references it",
              file=sys.stderr)
        return 1
    headings = {int(n) for n in HEADING.findall(design.read_text())}

    refs: dict[int, list[str]] = {}
    for path in sorted(root.rglob("*")):
        if path == design or path == Path(__file__).resolve() \
                or not path.is_file() or path.suffix not in SCAN_SUFFIXES:
            continue
        # match skip dirs against repo-relative parts only, so a skip
        # name appearing in the checkout's path prefix can't blank the
        # whole scan
        rel = path.relative_to(root)
        if SKIP_PARTS & set(rel.parts):
            continue
        text = path.read_text(errors="ignore")
        for n in REF.findall(text):
            refs.setdefault(int(n), []).append(str(rel))

    missing = {n: files for n, files in refs.items() if n not in headings}
    if missing:
        for n, files in sorted(missing.items()):
            print(f"FAIL: 'DESIGN.md section {n}' referenced by "
                  f"{', '.join(sorted(set(files)))} but DESIGN.md has no "
                  f"'Section {n}' heading", file=sys.stderr)
        return 1
    n_refs = sum(len(v) for v in refs.values())
    print(f"OK: {n_refs} DESIGN.md section references across "
          f"{len(refs)} sections all resolve "
          f"(headings present: {sorted(headings)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
