"""Per-line pragma suppressions: ``# repro: allow[<rule>] -- why``.

A pragma suppresses findings of the named rule(s) on its own line.
The justification after ``--`` is mandatory — a pragma without one is
itself a finding (rule ``bad-pragma``), as is a pragma naming a rule
that does not exist.  Multiple rules may be listed, comma-separated:

    x = int(jnp.sum(f))  # repro: allow[host-sync] -- one-time seed

The grammar is deliberately rigid (no bare ``allow``, no free-form
prose before the bracket) so suppressions stay greppable.
"""
from __future__ import annotations

import io
import re
import tokenize
from typing import Dict, Iterator, List, Set, Tuple

PRAGMA_RE = re.compile(r"#\s*repro:\s*(?P<body>.*)$")
ALLOW_RE = re.compile(
    r"^allow\[(?P<rules>[^\]]*)\]\s*(?:--\s*(?P<why>.*))?$")


def _comments(source: str) -> Iterator[Tuple[int, str]]:
    """``(lineno, text)`` for every comment token.  Tokenizing (not
    line-scanning) means pragma-shaped text inside string literals and
    docstrings is ignored."""
    try:
        for tok in tokenize.generate_tokens(
                io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.string
    except (tokenize.TokenError, SyntaxError):
        return  # unparseable tail: the linter reports parse-error


def parse_pragmas(
    source: str,
    known_rules: Set[str],
) -> Tuple[Dict[int, Set[str]], List[Tuple[int, str]]]:
    """Scan ``source`` for ``# repro:`` pragmas.

    Returns ``(allows, problems)`` where ``allows`` maps 1-based line
    numbers to the set of rule ids suppressed on that line and
    ``problems`` lists ``(line, message)`` pairs for malformed
    pragmas: unparseable body, empty rule list, unknown rule id, or a
    missing/empty justification.
    """
    allows: Dict[int, Set[str]] = {}
    problems: List[Tuple[int, str]] = []
    for lineno, text in _comments(source):
        m = PRAGMA_RE.search(text)
        if not m:
            continue
        body = m.group("body").strip()
        am = ALLOW_RE.match(body)
        if not am:
            problems.append(
                (lineno,
                 "malformed pragma: expected "
                 "`# repro: allow[<rule>] -- <justification>`"))
            continue
        rules = [r.strip() for r in am.group("rules").split(",")
                 if r.strip()]
        if not rules:
            problems.append(
                (lineno, "pragma allows no rules: `allow[]`"))
            continue
        unknown = [r for r in rules if r not in known_rules]
        if unknown:
            problems.append(
                (lineno,
                 f"pragma names unknown rule(s): "
                 f"{', '.join(sorted(unknown))}"))
            continue
        why = (am.group("why") or "").strip()
        if not why:
            problems.append(
                (lineno,
                 "pragma is missing its mandatory justification "
                 "(`-- <why>`)"))
            continue
        allows.setdefault(lineno, set()).update(rules)
    return allows, problems
