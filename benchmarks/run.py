"""Benchmark aggregator: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run table2 fig8 # subset
"""
from __future__ import annotations

import sys


def main() -> None:
    which = set(sys.argv[1:]) or {"table2", "table2sim", "fig5", "fig6",
                                  "fig8", "fig9", "roofline"}
    print("name,us_per_call,derived")
    if "table2" in which:
        from . import table2_strategies
        table2_strategies.run()
    if "table2sim" in which:
        from . import table2_simulated
        table2_simulated.run()
    if "fig5" in which:
        from . import fig5_load_distribution
        fig5_load_distribution.run()
    if "fig6" in which:
        from . import fig6_scaling
        fig6_scaling.run()
    if "fig8" in which:
        from . import fig8_cyclic_blocked
        fig8_cyclic_blocked.run()
    if "fig9" in which:
        from . import fig9_partition
        fig9_partition.run()
    if "roofline" in which:
        from . import roofline
        try:
            roofline.main()
        except Exception as e:       # artifacts may not exist yet
            print(f"roofline,0,skipped ({e})")


if __name__ == "__main__":
    main()
