"""Service-level instrumentation.

Where :class:`repro.core.balancer.RoundStats` measures one balancer
round, :class:`ServiceStats` measures the *service*: how many queries
were served (and how many straight from cache), the distribution of
rounds-in-system (queue wait + slot residency, the service's latency
in its natural unit), and how full the slot array ran (occupancy = the
fraction of slot-rounds that held a query — the utilization that
continuous batching exists to maximize, DESIGN.md section 8).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class ServiceStats:
    """Counters accumulated by a :class:`repro.serve.QueryService`."""
    queries_served: int = 0        # completed, including cache hits
    cache_hits: int = 0            # served with NO device work: LRU
    #                                hits + single-flight coalesced
    cache_misses: int = 0          # actually computed on the device
    steps: int = 0                 # service rounds executed
    slot_rounds_total: int = 0     # B per step (the capacity offered)
    slot_rounds_busy: int = 0      # ... of which held a RUNNING query
    preemptions: int = 0
    host_transfers: int = 0        # device->host syncs during stepping
    #                                (balancer round counts + liveness
    #                                probes; fused mode amortizes them
    #                                over whole chunks of rounds)
    rounds_in_system: List[int] = dataclasses.field(default_factory=list)

    def record_step(self, busy: int, total: int) -> None:
        """Account one service round offering ``total`` slot-rounds of
        which ``busy`` were occupied."""
        self.steps += 1
        self.slot_rounds_total += total
        self.slot_rounds_busy += busy

    def record_done(self, rounds_in_system: int,
                    from_cache: bool) -> None:
        """Account one completed query."""
        self.queries_served += 1
        if from_cache:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        self.rounds_in_system.append(int(rounds_in_system))

    @property
    def occupancy(self) -> float:
        """Busy slot-rounds / offered slot-rounds (0.0 before any
        step)."""
        if self.slot_rounds_total == 0:
            return 0.0
        return self.slot_rounds_busy / self.slot_rounds_total

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of completed queries answered from the cache."""
        if self.queries_served == 0:
            return 0.0
        return self.cache_hits / self.queries_served

    def latency_percentile(self, p: float) -> float:
        """p-th percentile of rounds-in-system over completed queries
        (NaN before any completion)."""
        if not self.rounds_in_system:
            return float("nan")
        return float(np.percentile(np.asarray(self.rounds_in_system), p))

    def summary(self) -> dict:
        """One flat dict for logging/benchmark emission."""
        return {
            "queries_served": self.queries_served,
            "cache_hit_rate": round(self.cache_hit_rate, 4),
            "steps": self.steps,
            "occupancy": round(self.occupancy, 4),
            "preemptions": self.preemptions,
            "host_transfers": self.host_transfers,
            "lat_rounds_p50": self.latency_percentile(50),
            "lat_rounds_p95": self.latency_percentile(95),
        }
