"""Multi-host bootstrap for real TPU pods.

On a real v5e pod slice each host runs the same program;
``jax.distributed.initialize`` wires them together.  This module reads
the standard launcher environment (GKE/TPU-VM or SLURM) and must be
called BEFORE any other jax API touches the backend.

Elastic restarts: the coordinator address is stable across restarts
(headless service / node 0); a restarted job re-initializes with a
possibly different ``num_processes`` and the checkpoint layer reshapes
(checkpoints store logical arrays, see checkpoint/ckpt.py).
"""
from __future__ import annotations

import os


def maybe_initialize_distributed() -> bool:
    """Initialize jax.distributed from the environment; returns True if
    multi-host mode was set up, False for single-host (no-op)."""
    import jax

    coord = os.environ.get("REPRO_COORDINATOR")      # host:port
    if coord is None and "SLURM_JOB_NODELIST" in os.environ:
        # SLURM: node 0 of the allocation is the coordinator
        first = os.environ["SLURM_JOB_NODELIST"].split(",")[0]
        first = first.split("[")[0] + \
            os.environ.get("SLURM_NODELIST_SUFFIX", "")
        coord = f"{first}:8476"
    if coord is None:
        return False

    num_procs = int(os.environ.get(
        "REPRO_NUM_PROCESSES",
        os.environ.get("SLURM_NTASKS", "1")))
    proc_id = int(os.environ.get(
        "REPRO_PROCESS_ID",
        os.environ.get("SLURM_PROCID", "0")))
    jax.distributed.initialize(coordinator_address=coord,
                               num_processes=num_procs,
                               process_id=proc_id)
    return True


def global_batch_slice(global_batch: int):
    """Rows of the global batch owned by this host (deterministic:
    pure function of process index, replay-safe across restarts)."""
    import jax

    nproc = jax.process_count()
    assert global_batch % nproc == 0, (global_batch, nproc)
    per = global_batch // nproc
    start = jax.process_index() * per
    return slice(start, start + per)
