"""Partitioner invariants (CuSP-analog, DESIGN.md section 6).

For every policy and device count the partition must be an exact
edge decomposition — per-device edge lists pairwise disjoint, union
reconstructing the input multigraph — and the PartitionMeta must
describe a consistent master/mirror structure: one contiguous owned
range per device covering all vertices, and mirror lists that contain
exactly the non-owned endpoints of each device's local edges.
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.partition import partition, partition_stats

POLICIES = ["oec", "iec", "cvc"]
DEVICE_COUNTS = [1, 2, 3, 4]


@pytest.fixture(scope="module", params=["rmat", "road"])
def graph(request):
    if request.param == "rmat":
        return G.rmat(8, 8, seed=7)
    return G.road_grid(12, seed=7)


def _device_coo(stacked, d):
    """Un-pad device d's local CSR back to a COO triple."""
    rp = np.asarray(stacked.row_ptr[d]).astype(np.int64)
    ne = int(rp[-1])
    src = np.repeat(np.arange(len(rp) - 1, dtype=np.int64), rp[1:] - rp[:-1])
    dst = np.asarray(stacked.col_idx[d]).astype(np.int64)[:ne]
    w = np.asarray(stacked.edge_w[d]).astype(np.int64)[:ne]
    return src, dst, w


def _sorted_triples(src, dst, w):
    order = np.lexsort((w, dst, src))
    return np.stack([src[order], dst[order], w[order]], axis=1)


@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
@pytest.mark.parametrize("policy", POLICIES)
def test_partition_is_exact_edge_decomposition(graph, policy, ndev):
    stacked, meta = partition(graph, ndev, policy)
    srcs, dsts, ws = [], [], []
    for d in range(ndev):
        s, t, w = _device_coo(stacked, d)
        srcs.append(s)
        dsts.append(t)
        ws.append(w)
    union = _sorted_triples(np.concatenate(srcs), np.concatenate(dsts),
                            np.concatenate(ws))
    gs, gd, gw = G.to_coo(graph)
    ref = _sorted_triples(gs, gd, gw.astype(np.int64))
    # disjoint + complete: multiset equality of (src, dst, w) triples
    assert union.shape == ref.shape
    np.testing.assert_array_equal(union, ref)
    # edge counts add up exactly (no edge on two devices)
    assert sum(len(s) for s in srcs) == graph.num_edges


@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
@pytest.mark.parametrize("policy", POLICIES)
def test_partition_meta_masters_and_mirrors(graph, policy, ndev):
    stacked, meta = partition(graph, ndev, policy)
    v = graph.num_vertices
    # contiguous owned ranges covering [0, V), consistent with owner map
    b = meta.master_bounds
    assert b[0] == 0 and b[-1] == v
    assert np.all(np.diff(b) >= 0)
    for d in range(ndev):
        assert np.all(meta.owner[b[d]:b[d + 1]] == d)
    # mirror lists: exactly the non-owned endpoints of local edges
    for d in range(ndev):
        s, t, _ = _device_coo(stacked, d)
        ends = np.unique(np.concatenate([s, t]))
        expected = set(ends[meta.owner[ends] != d].tolist())
        listed = set()
        for o in range(ndev):
            n = int(meta.mirror_counts[d, o])
            lst = meta.mirror_idx[d, o, :n]
            assert np.all(meta.owner[lst] == o)
            assert len(np.unique(lst)) == n
            assert np.all(meta.mirror_idx[d, o, n:] == v)   # padding
            listed |= set(lst.tolist())
        assert listed == expected
        assert not (set(range(b[d], b[d + 1])) & listed)    # never own+mirror


@pytest.mark.parametrize("policy", POLICIES)
def test_partition_stats_reports_replication_factor(graph, policy):
    stacked, meta = partition(graph, 4, policy)
    st = partition_stats(stacked, meta)
    assert st["replication_factor"] == pytest.approx(
        (graph.num_vertices + meta.total_mirrors) / graph.num_vertices)
    assert st["replication_factor"] >= 1.0
    assert len(st["mirrors_per_device"]) == 4
    # stats without meta still work (backwards-compatible shape)
    st2 = partition_stats(stacked)
    assert "replication_factor" not in st2
