"""Lint driver: parse files once, run every rule, apply pragmas.

:func:`analyze_source` is the unit tests' entry point (lint a string
under an arbitrary virtual path); :func:`analyze_paths` is the CLI's
(walk files/directories, share one :class:`Session` so cross-file
lookups like the scatter combine registry are parsed once).
"""
from __future__ import annotations

import ast
import os
from functools import cached_property
from typing import Dict, Iterable, List, Optional, Sequence

from . import astutil
from .findings import Finding
from .pragmas import parse_pragmas
from .registry import Rule, get_rules, rule_ids


class Session:
    """Per-run shared state (cross-file caches for rules)."""

    def __init__(self) -> None:
        self.memo: Dict = {}


class FileContext:
    """One parsed source file handed to every rule's ``check``."""

    def __init__(self, path: str, source: str, tree: ast.AST,
                 session: Session) -> None:
        self.path = path.replace(os.sep, "/")
        self.source = source
        self.tree = tree
        self.session = session

    @cached_property
    def pragma_info(self):
        """``(allows, problems)`` from :func:`parse_pragmas`."""
        return parse_pragmas(self.source, set(rule_ids()))

    @cached_property
    def jit_bindings(self):
        """Jit/pallas tracing sites in this module."""
        return astutil.collect_jit_bindings(self.tree)

    def in_dir(self, *parts: str) -> bool:
        """Whether the file lives under ``.../parts[0]/parts[1]/...``
        anywhere in its path (e.g. ``ctx.in_dir("repro", "serve")``)."""
        needle = "/" + "/".join(parts) + "/"
        return needle in "/" + self.path

    def finding(self, node, rule: str, message: str) -> Finding:
        """Build a :class:`Finding` at ``node`` (or an int line)."""
        line = node if isinstance(node, int) else node.lineno
        return Finding(path=self.path, line=line, rule=rule,
                       message=message)


def analyze_source(
    source: str,
    path: str,
    rules: Optional[Sequence[Rule]] = None,
    session: Optional[Session] = None,
    relaxed: bool = False,
) -> List[Finding]:
    """Lint one source string as if it lived at ``path``.

    Runs the selected rules, then applies per-line pragma
    suppressions.  Syntax errors produce a single ``parse-error``
    finding rather than raising.
    """
    if rules is None:
        rules = get_rules(relaxed=relaxed)
    if session is None:
        session = Session()
    norm = path.replace(os.sep, "/")
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(path=norm, line=e.lineno or 1,
                        rule="parse-error",
                        message=f"cannot parse file: {e.msg}")]
    ctx = FileContext(norm, source, tree, session)
    findings: List[Finding] = []
    for rule in rules:
        findings.extend(rule.check(ctx))
    allows, _problems = ctx.pragma_info
    findings = [f for f in findings
                if f.rule not in allows.get(f.line, ())]
    return sorted(findings)


def iter_python_files(paths: Iterable[str]) -> List[str]:
    """Expand files/directories into a sorted list of ``.py`` files.

    Raises ``FileNotFoundError`` for a path that does not exist (a
    misspelled CLI argument must not silently lint nothing).
    """
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d not in {"__pycache__", ".git"})
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        out.append(os.path.join(dirpath, fn))
        else:
            raise FileNotFoundError(p)
    return sorted(dict.fromkeys(out))


def analyze_paths(
    paths: Iterable[str],
    rules: Optional[Sequence[Rule]] = None,
    relaxed: bool = False,
) -> List[Finding]:
    """Lint every ``.py`` file under ``paths`` with one shared
    :class:`Session`; returns all findings, sorted."""
    session = Session()
    findings: List[Finding] = []
    for fp in iter_python_files(paths):
        with open(fp, "r", encoding="utf-8") as fh:
            source = fh.read()
        rel = os.path.relpath(fp) if not os.path.isabs(fp) else fp
        findings.extend(analyze_source(
            source, rel, rules=rules, session=session,
            relaxed=relaxed))
    return sorted(findings)
