"""paligemma-3b [vlm]: SigLIP frontend (STUB: input_specs supplies
precomputed patch embeddings) + gemma backbone.
[arXiv:2407.07726; hf]  18L d_model=2048 8H (kv=1) d_ff=16384
vocab=257216."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    d_ff=16384, vocab_size=257216, head_dim=256, act="gelu",
    prefix_len=256,                   # 256 image patch embeddings
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=1, d_ff=128, vocab_size=256,
                      head_dim=16, prefix_len=8)
