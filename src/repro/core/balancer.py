"""Adaptive Load Balancer (ALB) — the paper's core contribution, on TPU.

Four strategies (Section 3 + 4 of the paper):

* ``vertex``  — vertex-based distribution: every active vertex processed
  as one unit of work regardless of degree (Section 3.1 strawman).
* ``twc``     — Thread-Warp-CTA analog: active vertices binned by degree
  (small/medium/large); each bin processed with a uniform inner width.
  The large bin is UNBOUNDED, which is exactly the thread-block
  imbalance the paper fixes (Section 3.2).
* ``edge_lb`` — non-adaptive edge-balanced distribution (Gunrock-LB
  analog): ALL frontier edges are renumbered by prefix sum and dealt
  evenly (Section 3.3).
* ``alb``     — the paper's scheme: TWC bins for degree < THRESHOLD plus
  a ``huge`` bin; an inspector checks whether the huge bin is nonempty
  and only then runs the edge-balanced (LB) executor (Section 4).

TPU mapping (DESIGN.md section 2): GPU thread blocks -> Pallas grid
tiles; warps/threads -> VPU lanes; atomicMin -> XLA scatter-min;
the inspector -> a vector reduction + host/`lax.cond` dispatch; cyclic
vs blocked edge deal -> lane-major contiguous vs strided edge-id order.

Architecture (DESIGN.md section 3): a strategy is *planned* once —
``make_plan`` turns a :class:`BalancerConfig` into a :class:`RoundPlan`
of degree bins plus an LB mode — and *executed* by one of two
interchangeable executor pairs from the registry:

* ``xla``    — pure jnp building blocks (``_bin_pass`` / ``_lb_pass``),
* ``pallas`` — the mapping kernels in ``repro.kernels`` (selected by
  ``BalancerConfig.use_pallas``), registered lazily.

Each :class:`ExecutorPair` exposes every path twice:

* host entries (``bin_host`` / ``lb_host``): per-round host decisions +
  bucketed jit shapes — mirrors per-round GPU kernel launches; used by
  ``relax`` for the single-device wall-clock benchmarks.
* fully-jit entries (``bin_jit`` / ``lb_jit``): static capacities,
  traced chunk index, ``lax.cond`` inspector — used by ``relax_spmd``
  inside ``shard_map`` for the distributed (Gluon-analog) runtime.

Both rounds therefore run the *same* planner and the *same* executor
implementations; ``use_pallas=True`` routes the hot mapping loops
through the Pallas kernels in either mode.

Batched multi-source queries (DESIGN.md section 7): ``relax`` and
``relax_spmd`` also accept ``labels[B, V]`` / ``values[B, V]`` /
``frontier[B, V]`` — B independent queries over the shared CSR.  Bin
selection, the huge-bin inspector, and the LB prefix-sum deal all run
once over the **union** frontier; per-query activity is recovered by
gathering the ``[B, V]`` frontier mask at each enumerated edge's
anchor vertex, and candidates of inactive (vertex, query) pairs carry
the combiner's identity so skipping them is exact.  One kernel launch
therefore serves B queries instead of B launches serving one.

Traversal direction (DESIGN.md section 9): the same fused host counts
that drive the strategy's inspector also drive a Beamer-style
*direction* choice — ``BalancerConfig.direction`` is ``push`` (as the
operator is written), ``pull`` (the operator's pull twin over the
cached reverse CSR: gather value and activity at each in-edge's
source, combine at the anchor), or ``adaptive``
(:func:`resolve_direction` per round, no extra device sync).  Pull
enumeration is frontier-independent — every vertex with in-edges,
binned by in-degree — so it is planned once per graph and cached
(:func:`_pull_enum`).  For push min-combine operators the pull round
is bitwise equal to the push round.

The continuous-batching service (DESIGN.md section 8) leans on one
further property of the batched round: rows are *independent*.  A row
whose frontier is empty contributes no live candidates anywhere, so
its labels are frozen — which is what lets the serving engine retire a
converged query's slot and refill it mid-loop.  ``relax``'s
``return_active`` surfaces each row's entered-the-round liveness from
the fused host transfer the round already pays for (free
instrumentation for external loops; retirement itself is a post-round
fact the engine reads from the updated frontier).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .frontier import (next_bucket, compact, count, dirty_mask,
                       union_frontier)
from .operators import Operator, as_pull


@dataclasses.dataclass(frozen=True)
class BalancerConfig:
    """Everything that defines a load-balancing strategy instance; a
    frozen (hashable) value object, so it doubles as a jit static arg
    and as the ``strategy`` component of the serving-layer result-cache
    key (DESIGN.md section 8)."""
    strategy: str = "alb"            # vertex | twc | edge_lb | alb
    threshold: int = 1024            # paper: #threads launched
    small_width: int = 8             # thread-level bin
    medium_width: int = 128          # warp-level bin
    large_width: int = 1024          # CTA chunk width (per pass)
    distribution: str = "cyclic"     # cyclic | blocked (Section 4.1)
    num_tiles: int = 64              # "thread blocks" for stats/kernels
    use_pallas: bool = False         # route hot loops through Pallas
    lb_tile_edges: int = 2048        # edge tile per grid step (LB kernel)
    direction: str = "push"          # push | pull | adaptive (sec. 9)
    pull_alpha: int = 14             # adaptive: pull when m_f*alpha >= E
    pull_beta: int = 24              # adaptive: pull when n_f*beta >= V
    backend: Optional[str] = None    # xla | pallas | merge_path | None
    #                                  (None: derived from use_pallas)
    wire: str = "identity"           # sync wire codec: identity |
    #                                  delta | quantize[:<dtype>] |
    #                                  bitmap (DESIGN.md section 14)

    def __post_init__(self):
        assert self.strategy in ("vertex", "twc", "edge_lb", "alb")
        assert self.distribution in ("cyclic", "blocked")
        assert self.direction in ("push", "pull", "adaptive")
        assert self.backend in (None, "xla", "pallas", "merge_path")
        # syntax-level wire validation; the operator pairing (quantize
        # needs a declared safe narrowing) is checked at driver entry,
        # where the operator is known (repro.core.wire.get_codec)
        from .wire import validate_wire   # local: avoids import cycle
        validate_wire(self.wire)

    @property
    def executor(self) -> str:
        """Registry name of the backend this config routes through.

        An explicit ``backend`` wins; otherwise ``use_pallas`` selects
        between the classic ``xla`` and ``pallas`` pairs.  The third
        registered backend, ``merge_path``, replaces the whole
        plan/inspector machinery with equal-work edge tiles (see
        :func:`effective_plan`)."""
        if self.backend is not None:
            return self.backend
        return "pallas" if self.use_pallas else "xla"


# ---------------------------------------------------------------------------
# round planner — the ONE place a strategy is defined (both round modes
# consume the same plan)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BinSpec:
    """One degree bin of the vertex-binned (TWC-analog) path.

    A frontier vertex lands in the bin when ``lo < deg`` and (if ``hi``
    is set) ``deg <= hi``.  ``cap`` is a static upper bound on the
    degree of any member (used by the fully-jit round to fix the pass
    count); ``cap=None`` marks a genuinely unbounded bin, driven by a
    data-dependent number of width-``width`` passes.
    """
    name: str
    width: int
    lo: int
    hi: Optional[int] = None
    cap: Optional[int] = None

    def mask(self, deg: jax.Array, valid: jax.Array) -> jax.Array:
        """Membership mask of this bin over a frontier's degrees."""
        m = valid & (deg > self.lo)
        if self.hi is not None:
            m = m & (deg <= self.hi)
        return m

    def static_passes(self) -> Optional[int]:
        """Pass count for the fully-jit round; None = data-dependent."""
        if self.cap is None:
            return None
        return max(1, -(-self.cap // self.width))


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """Bins + LB mode for one strategy.

    ``lb``: ``"none"`` (no edge-balanced path), ``"all"`` (every
    frontier edge goes through LB — the non-adaptive Gunrock analog) or
    ``"huge"`` (only vertices with ``deg >= threshold`` — the paper's
    inspector-guarded adaptive path).

    ``direction``: the traversal-direction policy of the strategy
    instance (``push`` | ``pull`` | ``adaptive`` — DESIGN.md
    section 9); ``adaptive`` is resolved per round by
    :func:`resolve_direction` from the fused host counts.
    """
    bins: tuple
    lb: str
    direction: str = "push"

    def lb_mask(self, deg, valid, cfg: BalancerConfig):
        """Which frontier vertices the edge-balanced path serves."""
        if self.lb == "all":
            return valid & (deg > 0)
        if self.lb == "huge":
            return valid & (deg >= cfg.threshold)
        raise ValueError(self.lb)


def make_plan(cfg: BalancerConfig) -> RoundPlan:
    """Turn a config into the degree bins + LB mode of its strategy —
    the ONE place a strategy is defined (both round modes consume the
    same plan)."""
    s, sw, mw, lw, th = (cfg.strategy, cfg.small_width, cfg.medium_width,
                         cfg.large_width, cfg.threshold)
    d = cfg.direction
    if s == "vertex":
        # one unit of work per vertex, inner width = whole adjacency
        return RoundPlan((BinSpec("vertex", lw, 0),), "none", d)
    if s == "twc":
        return RoundPlan((BinSpec("small", sw, 0, sw, sw),
                          BinSpec("medium", mw, sw, mw, mw),
                          # CTA bin: UNBOUNDED — the paper's culprit
                          BinSpec("large", lw, mw)), "none", d)
    if s == "edge_lb":
        return RoundPlan((), "all", d)        # everything, non-adaptive
    # alb: bins must be DISJOINT with the huge bin or add-combine
    # operators double-count (min-combine would mask the bug)
    return RoundPlan((BinSpec("small", sw, 0, min(sw, th - 1), sw),
                      BinSpec("medium", mw, sw, min(mw, th - 1), mw),
                      BinSpec("large", lw, mw, th - 1, th)), "huge", d)


def effective_plan(cfg: BalancerConfig) -> RoundPlan:
    """The plan a round actually executes.

    Normally :func:`make_plan`'s strategy bins; under the
    ``merge_path`` backend the plan collapses to ``RoundPlan((),
    "all")`` regardless of strategy — merge-path partitions the
    frontier's whole edge range into equal-work tiles by co-ranked
    binary search over the CSR prefix sums, so it needs no degree bins
    and no huge-bin inspector.  Every frontier edge is still processed
    exactly once (the LB mask covers all ``deg > 0`` members), so
    add-combine operators stay exact."""
    if cfg.executor == "merge_path":
        return RoundPlan((), "all", cfg.direction)
    return make_plan(cfg)


def resolve_direction(cfg: BalancerConfig, frontier_size: int,
                      frontier_edges: int, num_vertices: int,
                      num_edges: int) -> str:
    """Per-round traversal-direction choice (DESIGN.md section 9).

    ``push`` / ``pull`` configs are fixed; ``adaptive`` applies
    Beamer-style direction-optimization thresholds to the union
    frontier: the round runs as a pull (gather over in-edges of the
    cached reverse CSR) when the frontier is dense by vertices
    (``frontier_size * pull_beta >= V``) or by out-edges
    (``frontier_edges * pull_alpha >= E``), and as a push otherwise.
    Both inputs ride the fused host-count transfer every round already
    pays (``_host_round_counts``), so adaptivity adds no device sync.
    """
    if cfg.direction != "adaptive":
        return cfg.direction
    if frontier_size * cfg.pull_beta >= num_vertices:
        return "pull"
    if frontier_edges * cfg.pull_alpha >= num_edges:
        return "pull"
    return "push"


def resolve_direction_device(cfg: BalancerConfig, frontier_size,
                             frontier_edges, num_vertices: int,
                             num_edges: int) -> jax.Array:
    """jit-traceable twin of :func:`resolve_direction`: the same Beamer
    thresholds over *device* int32 scalars, returning a bool scalar
    (True = pull) instead of a string — the branch selector the fused
    round feeds to ``lax.cond``.  Fixed directions fold to constants at
    trace time; the integer threshold arithmetic is exact, so the
    device choice is always identical to the host choice made from the
    fused count transfer.  (Counts are int32 on device — frontier sizes
    or edge totals beyond ``2**31 / max(alpha, beta)`` would need the
    x64 mode this repo does not enable.)"""
    if cfg.direction == "push":
        return jnp.asarray(False)
    if cfg.direction == "pull":
        return jnp.asarray(True)
    return ((frontier_size * cfg.pull_beta >= num_vertices)
            | (frontier_edges * cfg.pull_alpha >= num_edges))


# ---------------------------------------------------------------------------
# host-sync accounting: the per-round blocking device->host transfers
# each execution mode performs, as an assertable number (the structural
# realization of the "zero per-round host syncs" property of the fused
# mode — no wall-clock measurement involved)
# ---------------------------------------------------------------------------

_HOST_TRANSFERS = [0]


def _note_host_transfer(n: int = 1) -> None:
    """Record ``n`` blocking per-round device->host sync points.

    Called at every site that materializes device values on the host
    *inside* a round loop (the fused count vector of :func:`relax`, the
    liveness/stat fetch of :func:`relax_spmd_directed`, the per-round
    probes of the distributed and serving loops).  One-time amortized
    setup (e.g. the cached pull enumeration) and the final label fetch
    are deliberately NOT counted — ``host_transfers`` measures the
    per-round round-trip cost the fused mode eliminates."""
    _HOST_TRANSFERS[0] += n


def host_transfer_count() -> int:
    """Monotonic process-wide count of per-round device->host sync
    points (see :func:`_note_host_transfer`).  Callers measure a
    traversal's syncs as the delta across it; ``mode="fused"`` must
    leave the counter unchanged between dispatch and final fetch."""
    return _HOST_TRANSFERS[0]


# ---------------------------------------------------------------------------
# executor registry: XLA and Pallas implementations of the two paths,
# each with a host-driven and a fully-jit entry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutorPair:
    """One backend's implementations of the bin + LB paths.

    Every entry is **batched**: ``values`` / ``labels`` are ``[B, V]``
    and ``fmask`` is the ``[B, V]`` per-query frontier (the batch axis
    is always present; the round entry points add it for un-batched
    callers).  The vertex/edge enumeration arguments are batch-shared —
    they come from the union frontier — and per-query activity is
    recovered inside the entry by gathering ``fmask`` at each edge's
    anchor vertex.

    bin entries: (g, values, labels, fmask, bvidx, bdeg, brow, width,
                  op, chunk) -> labels, ``chunk`` a Python int (host)
                  or a traced int32 scalar (jit).
    lb entries:  (g, values, labels, fmask, hvidx, hdeg, hrow, total,
                  ecap, op, distribution, num_tiles, tile_edges)
                  -> labels.
    """
    name: str
    bin_host: Callable
    bin_jit: Callable
    lb_host: Callable
    lb_jit: Callable


_REGISTRY: dict = {}


def register_executor(pair: ExecutorPair) -> None:
    """Install (or replace) a named backend in the executor registry."""
    _REGISTRY[pair.name] = pair


def get_executor(name: str) -> ExecutorPair:
    """Look up a backend by name (``"xla"`` | ``"pallas"`` |
    ``"merge_path"``); the Pallas-backed pairs are registered lazily on
    first use to keep their import cost off the common path.

    ``merge_path`` routes every frontier edge through the co-ranked
    equal-work kernel (``kernels/merge_path.py``) — its plan has no
    bins (see :func:`effective_plan`), so its bin entries are
    unreachable and raise if ever called."""
    if name not in _REGISTRY and name in ("pallas", "merge_path"):
        from repro.kernels import ops as kops   # lazy: pallas import cost
        register_executor(ExecutorPair(
            "pallas",
            bin_host=kops.twc_bin_apply, bin_jit=kops.twc_bin_apply_static,
            lb_host=kops.edge_lb_apply, lb_jit=kops.edge_lb_apply_static))
        register_executor(ExecutorPair(
            "merge_path",
            bin_host=kops.merge_path_no_bins,
            bin_jit=kops.merge_path_no_bins,
            lb_host=kops.merge_path_apply,
            lb_jit=kops.merge_path_apply_static))
    return _REGISTRY[name]


class RoundStats(NamedTuple):
    """Instrumentation for Fig 1/5-style plots (host values).

    With a batched round (DESIGN.md section 7) ``frontier_size`` is the
    **union** frontier size (what drives the work done) and
    ``frontier_per_query`` holds the B per-query frontier sizes; the
    edge counts are union counts — each enumerated edge is processed
    once for the whole batch.
    """
    frontier_size: int
    edges_twc: int          # edges processed by the vertex-binned path
    edges_lb: int           # edges processed by the edge-balanced path
    lb_invoked: bool        # did the inspector fire the LB executor?
    tile_loads_twc: np.ndarray   # per-tile edge counts, TWC path
    tile_loads_lb: np.ndarray    # per-tile edge counts, LB path
    mirrors_synced: int = 0  # label entries exchanged by the BSP sync
    bytes_synced: int = 0    # ... as LOGICAL bytes: index word + [B]
    #                          payload per exchanged vertex (0 outside
    #                          the distributed runtime; see gluon.py /
    #                          DESIGN.md section 6)
    bytes_wire: int = 0      # POST-ENCODE bytes of the same exchange
    #                          under cfg.wire (== bytes_synced for the
    #                          identity codec; DESIGN.md section 14)
    frontier_per_query: Optional[np.ndarray] = None  # int64[B]
    direction: str = "push"  # traversal direction this round ran as
    #                          (DESIGN.md section 9)
    frontier_edges: int = 0  # union-frontier out-edge total (the push-
    #                          side m_f the direction choice is made on;
    #                          0 where the round had no host counts)
    host_transfers: int = 0  # blocking device->host sync points this
    #                          round performed (1 for host/spmd rounds,
    #                          0 for rounds inside the fused loop)

    @classmethod
    def from_device(cls, s: "RoundStatsDev") -> "RoundStats":
        """Materialize a jit-safe :class:`RoundStatsDev` on the host."""
        return cls(frontier_size=int(s.frontier_size),
                   edges_twc=int(s.edges_twc),
                   edges_lb=int(s.edges_lb),
                   lb_invoked=bool(s.lb_invoked),
                   tile_loads_twc=np.asarray(s.tile_loads_twc,
                                             dtype=np.int64),
                   tile_loads_lb=np.asarray(s.tile_loads_lb,
                                            dtype=np.int64),
                   mirrors_synced=int(s.mirrors_synced),
                   bytes_synced=int(s.bytes_synced),
                   bytes_wire=int(s.bytes_wire),
                   frontier_per_query=np.asarray(s.frontier_per_query,
                                                 dtype=np.int64),
                   direction="pull" if bool(s.is_pull) else "push",
                   frontier_edges=int(s.frontier_edges))


class RoundStatsDev(NamedTuple):
    """jit-safe RoundStats: every field is a device array, so the
    structure can cross ``jit`` / ``shard_map`` boundaries (the SPMD
    realization of the Fig 1/5 instrumentation).  The fused round loop
    (:func:`run_fused`) accumulates one of these per round into
    ``[max_rounds]``-leading buffers on device and transfers the whole
    structure once at convergence (:func:`fused_stats_host`)."""
    frontier_size: jax.Array     # int32 scalar (union size when batched)
    edges_twc: jax.Array         # int32 scalar
    edges_lb: jax.Array          # int32 scalar
    lb_invoked: jax.Array        # bool scalar
    tile_loads_twc: jax.Array    # int32[num_tiles]
    tile_loads_lb: jax.Array     # int32[num_tiles]
    mirrors_synced: jax.Array    # int32 scalar (filled in by gluon.py)
    bytes_synced: jax.Array      # int32 scalar (filled in by gluon.py)
    bytes_wire: jax.Array = np.int32(0)  # int32 scalar: post-encode
    #                              bytes under cfg.wire (gluon.py)
    frontier_per_query: jax.Array = np.zeros((1,), np.int32)  # int32[B]
    frontier_edges: jax.Array = np.int32(0)   # push-side m_f (union)
    is_pull: jax.Array = np.zeros((), bool)   # direction this round ran


# ---------------------------------------------------------------------------
# XLA building blocks (the "xla" executor; cached per static shape bucket)
# ---------------------------------------------------------------------------

@jax.jit
def _frontier_meta(g: Graph, frontier_idx: jax.Array):
    """degree / row start / validity for a compacted frontier."""
    v = g.row_ptr.shape[0] - 1
    valid = frontier_idx < v
    safe = jnp.where(valid, frontier_idx, 0)
    deg = jnp.where(valid, g.row_ptr[safe + 1] - g.row_ptr[safe], 0)
    row_start = jnp.where(valid, g.row_ptr[safe], 0)
    return deg, row_start, valid


def combine_neutral(combine: str, dtype):
    """Identity element of a combiner: a candidate that can never win a
    ``min`` (dtype max / +inf) or change an ``add`` (0).  Per-query
    masked slots of the batched scatter carry this value so skipping an
    inactive (vertex, query) pair is exact."""
    if combine == "min":
        if jnp.issubdtype(dtype, jnp.floating):
            return jnp.asarray(jnp.inf, dtype)
        return jnp.asarray(jnp.iinfo(dtype).max, dtype)
    if combine == "add":
        return jnp.asarray(0, dtype)
    raise ValueError(combine)


def _apply(labels, target, cand, emask, live, combine):
    """Batched scatter-combine (atomicMin/atomicAdd analog).

    labels : [B, V];  target/emask : batch-shared enumeration shape [S]
    (slots with ``emask`` False are dropped via the out-of-range
    sentinel); ``live`` : [B, *S-broadcastable] per-query activity —
    slots live for some queries but not others keep the shared target
    and carry the combiner's identity where inactive.
    """
    v = labels.shape[-1]
    tgt = jnp.where(emask, target, v)          # out of range => dropped
    full = live & emask[None]
    cand = cand.astype(labels.dtype)
    if combine == "min":
        cand = jnp.where(full, cand, combine_neutral("min", labels.dtype))
        return labels.at[:, tgt].min(cand, mode="drop")
    if combine == "add":
        return labels.at[:, tgt].add(jnp.where(full, cand, 0), mode="drop")
    raise ValueError(combine)


def _bin_pass_impl(g: Graph, values, labels, fmask, vidx, deg, row_start,
                   width: int, op: Operator, chunk):
    """Process one degree bin: each vertex in ``vidx`` contributes its
    edges [chunk*width, chunk*width + width) — the uniform-trip-count
    vertex-tiled path (TWC small/medium/large analog).  ``chunk`` may be
    a Python int or a traced int32 scalar.

    Shapes: values/labels/fmask: [B, V];  vidx/deg/row_start: [N]
    (union-frontier bin members);  produces an [N, width] edge tile
    shared by the whole batch.
    """
    v = labels.shape[-1]
    base = jnp.asarray(chunk, jnp.int32) * width
    off = base + jnp.arange(width, dtype=jnp.int32)[None, :]      # [1,W]
    emask = off < deg[:, None]                                     # [N,W]
    graph_e = jnp.where(emask, row_start[:, None] + off, 0)
    dst = g.col_idx[graph_e]
    w = g.edge_w[graph_e]
    vsafe = jnp.where(vidx < v, vidx, 0)
    if op.direction == "push":
        live = fmask[:, vsafe][:, :, None]                         # [B,N,1]
        val = values[:, vsafe][:, :, None]                         # [B,N,1]
        cand = op.msg(val, w[None])
        new = _apply(labels, dst, cand, emask, live, op.combine)
    else:  # pull: value AND activity gathered at the in-neighbour
        # (``dst`` in the reverse CSR is the original edge's source),
        # candidate scattered at the anchor — DESIGN.md section 9
        live = fmask[:, dst]                                       # [B,N,W]
        val = values[:, dst]                                       # [B,N,W]
        cand = op.msg(val, w[None])
        anchor = jnp.broadcast_to(vidx[:, None], emask.shape)
        new = _apply(labels, anchor, cand, emask, live, op.combine)
    return new


_bin_pass = partial(jax.jit, static_argnames=("width", "op"))(_bin_pass_impl)


def _lb_pass_impl(g: Graph, values, labels, fmask, hidx, hdeg, hrow_start,
                  total_edges, ecap: int, op: Operator,
                  distribution: str, num_tiles: int, tile_edges: int = 0):
    """The LB executor (Figure 3, SSSP_LB): edge-balanced renumbering.

    Edges of the huge vertices get global ids 0..total_edges-1 via an
    exclusive prefix sum over their degrees; each edge id is mapped back
    to (src, graph edge) by binary search (searchsorted) in that prefix
    array — the paper's CSR-preserving trick.  ``distribution`` controls
    the edge-id -> lane order (cyclic = consecutive lanes process
    consecutive edges; blocked = strided) — Section 4.1 / Figure 4.
    ``tile_edges`` is unused here (XLA has no grid); kept for executor
    signature parity with the Pallas pair.

    The prefix sum and the deal are computed once per round over the
    union frontier's huge bin; ``fmask[:, src]`` recovers which queries
    the edge's source is actually active in (DESIGN.md section 7).
    """
    v = labels.shape[-1]
    start_e = jnp.cumsum(hdeg) - hdeg                  # exclusive prefix
    # enumerate a multiple of num_tiles so the blocked permutation below
    # is a bijection of [0, n_enum) and cannot miss edges
    w_per = -(-ecap // num_tiles)
    n_enum = w_per * num_tiles
    eid = jnp.arange(n_enum, dtype=jnp.int32)
    if distribution == "blocked":
        # thread T_i gets the contiguous chunk [i*w_per, (i+1)*w_per):
        # lane-major order becomes strided by w_per (Figure 4 right).
        eid = (eid % num_tiles) * w_per + eid // num_tiles
    emask = eid < total_edges
    eid_c = jnp.where(emask, eid, 0)
    j = jnp.searchsorted(start_e, eid_c, side="right") - 1   # src slot
    j = jnp.clip(j, 0, hidx.shape[0] - 1)
    graph_e = hrow_start[j] + (eid_c - start_e[j])
    graph_e = jnp.where(emask, graph_e, 0)
    src = hidx[j]
    dst = g.col_idx[graph_e]
    w = g.edge_w[graph_e]
    ssafe = jnp.where(src < v, src, 0)
    if op.direction == "push":
        live = fmask[:, ssafe]                         # [B, n_enum]
        cand = op.msg(values[:, ssafe], w[None])
        return _apply(labels, dst, cand, emask, live, op.combine)
    else:
        # pull: liveness comes from the in-neighbour (``dst`` of the
        # reverse CSR), the anchor ``src`` receives the candidate
        live = fmask[:, dst]                           # [B, n_enum]
        cand = op.msg(values[:, dst], w[None])
        return _apply(labels, src, cand, emask, live, op.combine)


_lb_pass = partial(jax.jit, static_argnames=(
    "ecap", "op", "distribution", "num_tiles", "tile_edges"))(_lb_pass_impl)


register_executor(ExecutorPair("xla",
                               bin_host=_bin_pass, bin_jit=_bin_pass_impl,
                               lb_host=_lb_pass, lb_jit=_lb_pass_impl))


@partial(jax.jit, static_argnames=("num_tiles",))
def _tile_loads(deg, valid, num_tiles: int):
    """Per-tile edge counts when frontier vertices are dealt to tiles in
    compacted order (Fig 1/5 instrumentation)."""
    f = deg.shape[0]
    tile = (jnp.arange(f, dtype=jnp.int32) * num_tiles) // max(f, 1)
    return jnp.zeros((num_tiles,), jnp.int32).at[tile].add(
        jnp.where(valid, deg, 0).astype(jnp.int32))


def _lb_tile_loads(total, num_tiles: int):
    """Edge-balanced deal: per-tile loads differ by at most one edge."""
    total = jnp.asarray(total, jnp.int32)
    return (total // num_tiles
            + (jnp.arange(num_tiles, dtype=jnp.int32)
               < total % num_tiles).astype(jnp.int32))


# ---------------------------------------------------------------------------
# host-driven round (per-round "kernel launches", bucketed jit)
# ---------------------------------------------------------------------------

def _gather_bin_impl(mask, fidx, deg, row_start, cap: int, fcap: int,
                     v: int):
    """Compact a bin mask into (vidx, deg, row) at capacity ``cap``
    (slots past the bin size become out-of-range sentinels).  One fused
    kernel per (cap, fcap) bucket: the compaction and the three
    selector gathers used to run as ~9 separate dispatches per bin per
    round, which dominated small-frontier rounds — exactly the
    per-round fixed cost the batched/serving engines amortize."""
    sel = compact(mask, cap)                       # slots into fidx
    sel_safe = jnp.where(sel < fcap, sel, 0)
    take = sel < fcap
    return (jnp.where(take, fidx[sel_safe], v),
            jnp.where(take, deg[sel_safe], 0),
            jnp.where(take, row_start[sel_safe], 0))


# bucketed capacities keep the number of distinct (cap, fcap, v) keys
# small for any ONE graph, but a long-lived process touching many
# graphs/configs (the serving deployment, the benchmark sweeps) used to
# grow one compiled executable per key forever; the LRU bound below
# caps that at the _GATHER_BIN_CACHE_CAP hottest buckets
_GATHER_BIN_CACHE_CAP = 64
_GATHER_BIN_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()


def _gather_bin(mask, fidx, deg, row_start, cap: int, fcap: int, v: int):
    """LRU-bounded jit front of :func:`_gather_bin_impl`: one jitted
    closure per (cap, fcap, v) shape bucket, evicting the least
    recently used bucket (and its compiled executables) past
    ``_GATHER_BIN_CACHE_CAP`` entries."""
    key = (cap, fcap, v)
    fn = _GATHER_BIN_CACHE.pop(key, None)
    if fn is None:
        fn = jax.jit(partial(_gather_bin_impl, cap=cap, fcap=fcap, v=v))
        while len(_GATHER_BIN_CACHE) >= _GATHER_BIN_CACHE_CAP:
            _GATHER_BIN_CACHE.popitem(last=False)
    _GATHER_BIN_CACHE[key] = fn                    # most recently used
    return fn(mask, fidx, deg, row_start)


# the recompile-count gates (tests/test_streaming.py) watch jitted
# fns via _cache_size(); keep that introspection working across the
# LRU front by summing the live closures' trace counts
_gather_bin._cache_size = (                        # type: ignore[attr-defined]
    lambda: sum(f._cache_size() for f in _GATHER_BIN_CACHE.values()))


@partial(jax.jit, static_argnames=("cfg",))
def _host_round_counts(g: Graph, frontier: jax.Array, cfg: BalancerConfig):
    """Every host-side decision scalar of one round, fused into a single
    int32 vector so ``relax`` pays ONE device->host transfer per round
    (instead of one blocking ``int(jnp.sum(...))`` per bin plus the
    frontier count and inspector sums).

    Layout: ``[union_frontier_count,
               (bin_count, bin_max_deg, bin_edge_sum) per plan bin...,
               huge_count, huge_edge_sum (when the plan has an LB path),
               per-query frontier counts (B entries, batched input only)]``

    A batched ``[B, V]`` frontier is reduced to its union first — the
    bins and the inspector see one frontier for the whole batch
    (DESIGN.md section 7); the per-query counts ride along in the same
    transfer for the instrumentation.  The union mask is returned
    alongside so the caller's compaction reuses this one reduction.
    """
    deg = g.row_ptr[1:] - g.row_ptr[:-1]
    union = union_frontier(frontier)
    plan = effective_plan(cfg)
    vals = [count(union)]
    for spec in plan.bins:
        m = spec.mask(deg, union)
        md = jnp.where(m, deg, 0)
        vals += [jnp.sum(m.astype(jnp.int32)), jnp.max(md), jnp.sum(md)]
    if plan.lb != "none":
        hm = plan.lb_mask(deg, union, cfg)
        vals += [jnp.sum(hm.astype(jnp.int32)),
                 jnp.sum(jnp.where(hm, deg, 0))]
    head = jnp.stack([jnp.asarray(v, jnp.int32) for v in vals])
    if frontier.ndim == 1:
        return head, union
    return jnp.concatenate(
        [head, jnp.sum(frontier.astype(jnp.int32), axis=1)]), union


def _counts_frontier_edges(cnt: np.ndarray, plan: RoundPlan) -> int:
    """Union-frontier out-edge total, reassembled from the fused host
    count layout of :func:`_host_round_counts` (per-bin edge sums plus
    the LB-path sum) — the ``m_f`` input of :func:`resolve_direction`.
    The plan's bins and LB mask partition the frontier's edges for
    every strategy, so the sum is exact."""
    k, total = 1, 0
    for _ in plan.bins:
        total += int(cnt[k + 2])
        k += 3
    if plan.lb != "none":
        total += int(cnt[k + 1])
    return total


class _PullEnum(NamedTuple):
    """Frontier-independent pull-side enumeration of one (graph, plan):
    the reverse CSR plus pre-gathered bin/LB member arrays over every
    vertex with incoming edges, binned by IN-degree (DESIGN.md
    section 9).  A pull round gathers at each in-edge's source, so its
    work set never depends on the frontier — it is built once per
    graph x plan (one blocking transfer, amortized) and cached on the
    Graph object, keeping pull rounds free of per-round device syncs
    and per-round gather dispatches."""
    rg: Graph
    emask: jax.Array     # bool[V]: in-degree > 0 (the enumeration set)
    bins: tuple          # per plan bin: None | (max_d, edge_sum,
    #                      bvidx, bdeg, brow) at bucketed capacity
    lb: Optional[tuple]  # None | (total, hvidx, hdeg, hrow)


def _pull_plan_key(cfg: BalancerConfig) -> tuple:
    """The cfg fields a pull enumeration depends on (the plan's bins +
    LB mask); direction/deal fields deliberately excluded so
    push/adaptive variants share one cache entry.  The xla and pallas
    backends share entries too (same plan), but ``merge_path`` replaces
    the plan (no bins, LB = all — :func:`effective_plan`), so its
    enumeration is keyed separately."""
    return (cfg.strategy, cfg.threshold, cfg.small_width,
            cfg.medium_width, cfg.large_width,
            cfg.executor == "merge_path")


def _assemble_bins(cnt: np.ndarray, plan: RoundPlan,
                   cfg: BalancerConfig, fidx, deg, row_start, valid,
                   fcap: int, v: int):
    """Gather the bin / LB member arrays named by the fused host count
    vector (the :func:`_host_round_counts` layout: per-bin triplets,
    then the inspector pair).  Returns ``(bins, lb)`` in the
    :func:`_run_plan_host` format — the ONE assembly shared by the push
    round (per round, over the frontier) and the cached pull
    enumeration (once per graph), so the count layout can never
    desynchronize between them."""
    bins, k = [], 1
    for spec in plan.bins:
        n, max_d, edge_sum = int(cnt[k]), int(cnt[k + 1]), int(cnt[k + 2])
        k += 3
        if n == 0:
            bins.append(None)
            continue
        mask = spec.mask(deg, valid)
        bvidx, bdeg, brow = _gather_bin(mask, fidx, deg, row_start,
                                        next_bucket(n), fcap, v)
        bins.append((max_d, edge_sum, bvidx, bdeg, brow))
    lb = None
    if plan.lb != "none":
        # ---- inspector (Section 4.1): is the huge bin non-empty? ----
        n_huge, total = int(cnt[k]), int(cnt[k + 1])
        if n_huge > 0 and total > 0:
            hmask = plan.lb_mask(deg, valid, cfg)
            hvidx, hdeg, hrow = _gather_bin(hmask, fidx, deg, row_start,
                                            next_bucket(n_huge), fcap, v)
            lb = (total, hvidx, hdeg, hrow)
    return tuple(bins), lb


def _build_pull_enum(g: Graph, cfg: BalancerConfig) -> _PullEnum:
    """Materialize the pull-side enumeration (see :class:`_PullEnum`)."""
    rg = g.reverse()
    v = rg.num_vertices
    emask = (rg.row_ptr[1:] - rg.row_ptr[:-1]) > 0
    cnt, union = _host_round_counts(rg, emask, cfg)
    cnt = np.asarray(cnt)
    fcap = next_bucket(int(cnt[0]))
    fidx = compact(union, fcap)
    deg, row_start, valid = _frontier_meta(rg, fidx)
    bins, lb = _assemble_bins(cnt, effective_plan(cfg), cfg, fidx, deg,
                              row_start, valid, fcap, v)
    return _PullEnum(rg, emask, bins, lb)


def _pull_enum(g: Graph, cfg: BalancerConfig) -> _PullEnum:
    """Cached :func:`_build_pull_enum` (on the Graph object, keyed by
    ``g.version`` plus the plan-relevant cfg fields).

    The version component is the invalidation hook for streaming
    mutations (DESIGN.md section 10): an in-place topology change bumps
    ``g.version``, so every enumeration built for the old topology
    misses and is dropped — without it a pull round after a mutation
    would keep binning the stale reverse CSR."""
    cache = g.__dict__.get("_pull_enum_cache")
    if cache is None:
        cache = {}
        object.__setattr__(g, "_pull_enum_cache", cache)
    key = (g.version,) + _pull_plan_key(cfg)
    if key not in cache:
        for stale in [k for k in cache if k[0] != g.version]:
            del cache[stale]          # unreachable versions: drop
        cache[key] = _build_pull_enum(g, cfg)
    return cache[key]


def _run_plan_host(gr: Graph, values, labels, fmask, plan: RoundPlan,
                   cfg: BalancerConfig, op: Operator, ex: ExecutorPair,
                   bins, lb, stats) -> jax.Array:
    """Drive one host round's executor launches from pre-gathered
    bin/LB member arrays — shared by the push path (members gathered
    from this round's frontier) and the pull path (members cached per
    graph by :func:`_pull_enum`).  ``stats`` is the mutable RoundStats
    dict or None."""
    v = labels.shape[-1]
    for spec, entry in zip(plan.bins, bins):
        if entry is None:
            continue
        max_d, edge_sum, bvidx, bdeg, brow = entry
        passes = max(1, -(-max_d // spec.width))
        for c in range(passes):
            labels = ex.bin_host(gr, values, labels, fmask, bvidx,
                                 bdeg, brow, spec.width, op, c)
        if stats is not None:
            stats["edges_twc"] += edge_sum
            stats["tile_loads_twc"] += np.asarray(
                _tile_loads(bdeg, bvidx < v, cfg.num_tiles))
    if lb is not None:
        total, hvidx, hdeg, hrow = lb
        ecap = next_bucket(total, minimum=cfg.lb_tile_edges)
        labels = ex.lb_host(gr, values, labels, fmask, hvidx, hdeg,
                            hrow, jnp.int32(total), ecap, op,
                            cfg.distribution, cfg.num_tiles,
                            cfg.lb_tile_edges)
        if stats is not None:
            stats["edges_lb"] = total
            stats["lb_invoked"] = True
            stats["tile_loads_lb"] = np.asarray(
                _lb_tile_loads(total, cfg.num_tiles), dtype=np.int64)
    return labels


def relax(g: Graph, values: jax.Array, labels: jax.Array,
          frontier: jax.Array, cfg: BalancerConfig, op: Operator,
          collect_stats: bool = False, return_active: bool = False):
    """One round: apply ``op`` along all edges of active vertices.

    Returns (new_labels, RoundStats|None).  ``values`` is the per-vertex
    quantity being propagated (may alias ``labels``); ``labels`` is the
    array updated by scatter-combine.

    Batched form (DESIGN.md section 7): with ``labels``/``values``/
    ``frontier`` of shape ``[B, V]`` the round serves B independent
    queries from ONE set of launches — bins, inspector, and the LB deal
    are planned on the union frontier and the executors recover
    per-query activity from the ``[B, V]`` mask.  The returned labels
    keep the batch axis.

    Traversal direction (DESIGN.md section 9): with
    ``cfg.direction="pull"`` (or ``"adaptive"`` resolving to pull for
    this round — :func:`resolve_direction` over the same fused host
    counts, no extra sync) the round runs the operator's pull twin over
    the cached reverse CSR: enumeration covers every vertex with
    incoming edges (binned by in-degree, cached per graph), the
    executors gather value AND activity at each in-edge's source and
    combine at the anchor.  Only push ``min``-combine operators may be
    flipped; the result is bitwise equal to the push round's.

    ``return_active=True`` appends a host ``bool[B]`` (``bool[1]`` for
    the un-batched form) marking which rows entered the round with a
    non-empty frontier — per-slot liveness instrumentation for round
    loops over batched state (DESIGN.md section 8).  It is sliced out
    of the fused host-transfer the round already performs, so
    observing it costs no extra device round-trip.
    """
    batched = labels.ndim == 2
    if not batched:
        values, labels, frontier = (values[None], labels[None],
                                    frontier[None])
    b, v = labels.shape
    plan = effective_plan(cfg)
    # validate direction x operator up front (even when adaptive ends
    # up resolving to push every round, a bad pairing is a config bug)
    pull_op = as_pull(op) if cfg.direction != "push" else None
    cnt, union = _host_round_counts(g, frontier, cfg)
    cnt = np.asarray(cnt)
    _note_host_transfer()              # THE per-round host sync point
    nf = int(cnt[0])                                   # union size
    active = cnt[-b:] > 0
    if nf == 0:
        out = ((labels if batched else labels[0]), None)
        return out + (active,) if return_active else out
    m_f = _counts_frontier_edges(cnt, plan)
    direction = resolve_direction(cfg, nf, m_f, v, g.num_edges)

    ex = get_executor(cfg.executor)
    stats = dict(frontier_size=nf, edges_twc=0, edges_lb=0,
                 lb_invoked=False,
                 tile_loads_twc=np.zeros(cfg.num_tiles, np.int64),
                 tile_loads_lb=np.zeros(cfg.num_tiles, np.int64),
                 frontier_per_query=cnt[-b:].astype(np.int64),
                 direction=direction,
                 frontier_edges=m_f,
                 host_transfers=1) if collect_stats else None

    if direction == "pull":
        pe = _pull_enum(g, cfg)
        labels = _run_plan_host(pe.rg, values, labels, frontier, plan,
                                cfg, pull_op, ex, pe.bins, pe.lb, stats)
    else:
        fcap = next_bucket(nf)
        fidx = compact(union, fcap)
        deg, row_start, valid = _frontier_meta(g, fidx)
        bins, lb = _assemble_bins(cnt, plan, cfg, fidx, deg, row_start,
                                  valid, fcap, v)
        labels = _run_plan_host(g, values, labels, frontier, plan, cfg,
                                op, ex, bins, lb, stats)
    labels = labels if batched else labels[0]
    out = (labels, RoundStats(**stats) if stats is not None else None)
    return out + (active,) if return_active else out


# ---------------------------------------------------------------------------
# fully-jit SPMD round (for shard_map / distributed execution)
# ---------------------------------------------------------------------------

def _relax_spmd_impl(g: Graph, values: jax.Array, labels: jax.Array,
                     frontier: jax.Array, cfg: BalancerConfig,
                     op: Operator, collect_stats: bool = False,
                     return_dirty: bool = False,
                     emask: Optional[jax.Array] = None):
    """Static-shape ALB round: capacities fixed at V/E, LB path guarded
    by ``lax.cond``, unbounded bins driven by ``lax.while_loop`` — the
    SPMD realization of the inspector-executor split.  Runs the same
    :func:`make_plan` output through the registry's fully-jit executor
    entries, so all four strategies (and both the XLA and Pallas
    backends) are available inside ``shard_map``.

    Returns ``labels``, extended to ``(labels, RoundStatsDev)`` with
    ``collect_stats=True`` and/or ``(..., dirty)`` with
    ``return_dirty=True`` — ``dirty`` is the jit-safe changed-label
    bitvector the master/mirror sync exchanges over (DESIGN.md
    section 6).  ``tile_loads_twc`` reflects this mode's actual deal —
    bin members spread over tiles in static capacity-V slot order — so
    it is comparable across rounds/devices but not bit-identical to the
    host round's bucketed-compacted deal; the LB-path loads use the
    same balanced formula in both modes.

    Like :func:`relax`, accepts batched ``[B, V]`` labels/values/
    frontier (DESIGN.md section 7): the static-capacity enumeration,
    the ``lax.while_loop`` chunk driver, and the ``lax.cond`` inspector
    all run once on the union frontier for the whole batch; ``dirty``
    and the returned labels keep the batch axis.

    ``emask`` (DESIGN.md section 9) decouples the *enumeration* set
    from the frontier: a pull round passes the reverse CSR as ``g``,
    the pull twin of its operator, and ``emask`` = the ``bool[V]``
    in-degree mask — vertices are enumerated from ``emask`` while the
    executors still gather per-query activity from ``frontier``.
    ``None`` (the default, and every push round) enumerates the union
    frontier as before.  :func:`relax_spmd_directed` wraps this with
    the per-round direction resolution, and the fused traversal loop
    (:func:`run_fused`) inlines this body — it is a plain traceable
    function; ``relax_spmd`` is its top-level jitted form.
    """
    batched = labels.ndim == 2
    if not batched:
        values, labels, frontier = (values[None], labels[None],
                                    frontier[None])
    labels_in = labels
    v = labels.shape[-1]
    union = union_frontier(frontier)
    fidx = compact(union if emask is None else emask, v)
    deg, row_start, valid = _frontier_meta(g, fidx)

    ex = get_executor(cfg.executor)
    plan = effective_plan(cfg)
    edges_twc = jnp.int32(0)
    tl_twc = jnp.zeros((cfg.num_tiles,), jnp.int32)

    for spec in plan.bins:
        mask = spec.mask(deg, valid)
        bvidx = jnp.where(mask, fidx, v)
        bdeg = jnp.where(mask, deg, 0)
        brow = jnp.where(mask, row_start, 0)
        passes = spec.static_passes()
        if passes is not None:
            for c in range(passes):
                labels = ex.bin_jit(g, values, labels, frontier, bvidx,
                                    bdeg, brow, spec.width, op,
                                    jnp.int32(c))
        else:
            # unbounded bin: data-dependent pass count (0 when empty)
            max_d = jnp.max(bdeg)

            def cond(carry, _w=spec.width, _m=max_d):
                c, _ = carry
                return c * _w < _m

            def body(carry, _s=spec, _b=(bvidx, bdeg, brow)):
                c, lab = carry
                lab = ex.bin_jit(g, values, lab, frontier, *_b,
                                 _s.width, op, c)
                return c + 1, lab

            _, labels = jax.lax.while_loop(
                cond, body, (jnp.int32(0), labels))
        if collect_stats:
            edges_twc = edges_twc + jnp.sum(bdeg).astype(jnp.int32)
            tl_twc = tl_twc + _tile_loads(bdeg, mask, cfg.num_tiles)

    edges_lb = jnp.int32(0)
    lb_invoked = jnp.asarray(False)
    tl_lb = jnp.zeros((cfg.num_tiles,), jnp.int32)
    if plan.lb != "none":
        hmask = plan.lb_mask(deg, valid, cfg)
        n_huge = jnp.sum(hmask.astype(jnp.int32))
        ecap = g.col_idx.shape[0]
        hvidx = jnp.where(hmask, fidx, v)
        hdeg = jnp.where(hmask, deg, 0)
        hrow = jnp.where(hmask, row_start, 0)
        total = jnp.sum(hdeg)

        def lb_branch(labels):
            new = ex.lb_jit(g, values, labels, frontier, hvidx, hdeg,
                            hrow, total, ecap, op, cfg.distribution,
                            cfg.num_tiles, cfg.lb_tile_edges)
            return new, total.astype(jnp.int32), \
                _lb_tile_loads(total, cfg.num_tiles)

        def skip_branch(labels):
            return labels, jnp.int32(0), \
                jnp.zeros((cfg.num_tiles,), jnp.int32)

        labels, edges_lb, tl_lb = jax.lax.cond(
            n_huge > 0, lb_branch, skip_branch, labels)
        lb_invoked = n_huge > 0

    outs = (labels if batched else labels[0],)
    if collect_stats:
        outs += (RoundStatsDev(
            frontier_size=jnp.sum(union.astype(jnp.int32)),
            edges_twc=edges_twc, edges_lb=edges_lb,
            lb_invoked=lb_invoked,
            tile_loads_twc=tl_twc, tile_loads_lb=tl_lb,
            mirrors_synced=jnp.int32(0), bytes_synced=jnp.int32(0),
            frontier_per_query=jnp.sum(frontier.astype(jnp.int32),
                                       axis=1)),)
    if return_dirty:
        dirty = dirty_mask(labels_in, labels)
        outs += (dirty if batched else dirty[0],)
    return outs[0] if len(outs) == 1 else outs


relax_spmd = partial(jax.jit, static_argnames=(
    "cfg", "op", "collect_stats", "return_dirty"))(_relax_spmd_impl)


# ---------------------------------------------------------------------------
# device-resident planning: direction resolved by lax.cond over the
# on-device counts, whole traversals fused into one lax.while_loop
# ---------------------------------------------------------------------------

def relax_fused_round(g: Graph, rg: Optional[Graph],
                      emask: Optional[jax.Array], values: jax.Array,
                      labels: jax.Array, frontier: jax.Array,
                      cfg: BalancerConfig, op: Operator,
                      pull_op: Optional[Operator] = None,
                      collect_stats: bool = False):
    """One balancer round with the *entire* inspector on device — the
    trace-safe round primitive of the fused traversal loop (DESIGN.md
    section 11).

    The union-frontier count ``n_f`` and out-edge total ``m_f`` are
    computed as device scalars, the Beamer direction rule becomes a
    ``lax.cond`` branch selector (:func:`resolve_direction_device`),
    and each branch inlines the static-shape SPMD round
    (:func:`_relax_spmd_impl`) — push on ``g``, pull on the cached
    reverse CSR ``rg`` with its in-degree ``emask``.  Nothing here
    touches the host, so the caller can wrap any number of these rounds
    in one ``lax.while_loop``.

    Inputs are batched ``[B, V]`` (callers canonicalize); ``rg`` /
    ``emask`` / ``pull_op`` may be None for ``direction="push"``
    configs.  Returns ``(labels, is_pull, n_f, m_f, stats)`` — all
    device values; ``stats`` is a :class:`RoundStatsDev` with
    ``frontier_edges`` / ``is_pull`` filled in (None unless
    ``collect_stats``)."""
    v = labels.shape[-1]
    deg = g.row_ptr[1:] - g.row_ptr[:-1]
    union = union_frontier(frontier)
    nf = count(union)
    m_f = jnp.sum(jnp.where(union, deg, 0)).astype(jnp.int32)
    is_pull = resolve_direction_device(cfg, nf, m_f, v, g.num_edges)
    if cfg.direction == "push":
        out = _relax_spmd_impl(g, values, labels, frontier, cfg, op,
                               collect_stats=collect_stats)
    elif cfg.direction == "pull":
        out = _relax_spmd_impl(rg, values, labels, frontier, cfg,
                               pull_op, collect_stats=collect_stats,
                               emask=emask)
    else:
        out = jax.lax.cond(
            is_pull,
            lambda val, lab, fr: _relax_spmd_impl(
                rg, val, lab, fr, cfg, pull_op,
                collect_stats=collect_stats, emask=emask),
            lambda val, lab, fr: _relax_spmd_impl(
                g, val, lab, fr, cfg, op, collect_stats=collect_stats),
            values, labels, frontier)
    if collect_stats:
        labels_out, st = out
        st = st._replace(frontier_edges=m_f, is_pull=is_pull)
    else:
        labels_out, st = out, None
    return labels_out, is_pull, nf, m_f, st


def _fused_stats_init(max_rounds: int, b: int, num_tiles: int
                      ) -> RoundStatsDev:
    """Device-resident per-round stat buffers of a fused traversal:
    a :class:`RoundStatsDev` whose every leaf gained a leading
    ``[max_rounds]`` round axis, zero-filled."""
    z = partial(jnp.zeros, dtype=jnp.int32)
    return RoundStatsDev(
        frontier_size=z((max_rounds,)),
        edges_twc=z((max_rounds,)), edges_lb=z((max_rounds,)),
        lb_invoked=jnp.zeros((max_rounds,), bool),
        tile_loads_twc=z((max_rounds, num_tiles)),
        tile_loads_lb=z((max_rounds, num_tiles)),
        mirrors_synced=z((max_rounds,)), bytes_synced=z((max_rounds,)),
        bytes_wire=z((max_rounds,)),
        frontier_per_query=z((max_rounds, b)),
        frontier_edges=z((max_rounds,)),
        is_pull=jnp.zeros((max_rounds,), bool))


@partial(jax.jit, static_argnames=("cfg", "op", "pull_op", "max_rounds",
                                   "collect_stats"))
def _run_fused_loop(g: Graph, rg, emask, labels, frontier,
                    cfg: BalancerConfig, op: Operator, pull_op,
                    max_rounds: int, collect_stats: bool):
    """The fused min-combine convergence loop: ONE ``lax.while_loop``
    whose body is :func:`relax_fused_round` plus the ``new < old``
    frontier update; stats rows are written into the device buffers at
    the round index.  The loop condition probes the union frontier on
    device, so between dispatch and the caller's final fetch no value
    ever crosses to the host."""
    st0 = (_fused_stats_init(max_rounds, labels.shape[0], cfg.num_tiles)
           if collect_stats else None)

    def cond(carry):
        r, lab, fr, st = carry
        return (r < max_rounds) & jnp.any(fr)

    def body(carry):
        r, lab, fr, st = carry
        new, _, _, _, row = relax_fused_round(
            g, rg, emask, lab, lab, fr, cfg, op, pull_op, collect_stats)
        if collect_stats:
            st = jax.tree_util.tree_map(
                lambda buf, x: buf.at[r].set(x), st, row)  # repro: allow[scatter-determinism] -- round index r is unique per iteration, no duplicate targets
        return r + 1, new, new < lab, st

    r, labels, frontier, st = jax.lax.while_loop(
        cond, body, (jnp.int32(0), labels, frontier, st0))
    return labels, frontier, r, st


def run_fused(g: Graph, labels: jax.Array, frontier: jax.Array,
              cfg: BalancerConfig, op: Operator,
              max_rounds: int = 10_000, collect_stats: bool = False):
    """Run a whole min-combine traversal as ONE fused device loop —
    zero per-round host syncs (DESIGN.md section 11).

    Bin selection, the huge-bin inspector, and the push/pull direction
    rule all run on device (:func:`relax_fused_round`), so the
    multi-round loop needs no host round-trips: the only transfers are
    the dispatch of this call and whatever the caller fetches from the
    result.  Accepts ``[V]`` or batched ``[B, V]`` state like
    :func:`relax`.  The one-time pull enumeration (``direction`` pull /
    adaptive) is built before dispatch and cached per graph.

    Returns ``(labels, frontier, rounds, stats)`` — ``rounds`` is a
    device scalar and ``stats`` the device-accumulated
    :class:`RoundStatsDev` buffers (None unless ``collect_stats``);
    materialize them with :func:`fused_stats_host` once converged."""
    if op.combine != "min":
        raise ValueError(f"run_fused drives min-combine loops; got "
                         f"{op.name} (combine={op.combine!r})")
    batched = labels.ndim == 2
    lab = labels if batched else labels[None]
    fr = frontier if batched else frontier[None]
    pull_op = as_pull(op) if cfg.direction != "push" else None
    if cfg.direction != "push":
        pe = _pull_enum(g, cfg)
        rg, emask = pe.rg, pe.emask
    else:
        rg, emask = None, None
    lab, fr, r, st = _run_fused_loop(g, rg, emask, lab, fr, cfg=cfg,
                                     op=op, pull_op=pull_op,
                                     max_rounds=int(max_rounds),
                                     collect_stats=collect_stats)
    if not batched:
        lab, fr = lab[0], fr[0]
    return lab, fr, r, st


def fused_stats_host(st: Optional[RoundStatsDev], rounds: int):
    """Materialize a fused traversal's device-accumulated stat buffers
    as the usual per-round ``List[RoundStats]`` — ONE transfer for the
    whole traversal, after convergence (vs one per round in host/spmd
    mode).  ``rounds`` (the loop's round count) selects the filled
    prefix of the ``[max_rounds]`` buffers; fused rounds report
    ``host_transfers=0`` by construction."""
    if st is None:
        return None
    host = jax.tree_util.tree_map(np.asarray, st)
    return [RoundStats.from_device(
                RoundStatsDev(*[leaf[r] for leaf in host]))
            for r in range(int(rounds))]


@partial(jax.jit, static_argnames=("cfg", "op", "pull_op",
                                   "collect_stats"))
def _directed_round_jit(g: Graph, rg, emask, values, labels, frontier,
                        cfg: BalancerConfig, op: Operator, pull_op,
                        collect_stats: bool):
    """One device-directed round plus the per-row liveness of the
    entering frontier — the jitted body behind
    :func:`relax_spmd_directed`."""
    labels_out, is_pull, nf, m_f, st = relax_fused_round(
        g, rg, emask, values, labels, frontier, cfg, op, pull_op,
        collect_stats)
    return labels_out, is_pull, m_f, jnp.any(frontier, axis=-1), st


def relax_spmd_directed(g: Graph, values: jax.Array, labels: jax.Array,
                        frontier: jax.Array, cfg: BalancerConfig,
                        op: Operator, collect_stats: bool = False,
                        return_active: bool = False):
    """Direction-aware fully-jit round (DESIGN.md section 9): the round
    primitive behind ``mode="spmd"`` in the app drivers.

    The direction choice now lives on device — the same
    ``lax.cond``-over-device-counts path the fused loop uses
    (:func:`relax_fused_round`), so an ``adaptive`` config no longer
    pays a host count transfer to *decide*; the host-driven loop around
    this round still syncs once per round to *observe* liveness and
    stats, and only when it asks for them (``return_active`` /
    ``collect_stats``).

    Returns ``(labels, RoundStats|None)`` — host stats with
    ``direction`` and the push-side ``frontier_edges`` filled in —
    extended by a host ``bool[B]`` liveness vector when
    ``return_active=True``."""
    batched = labels.ndim == 2
    if not batched:
        values, labels, frontier = (values[None], labels[None],
                                    frontier[None])
    pull_op = as_pull(op) if cfg.direction != "push" else None
    if cfg.direction != "push":
        pe = _pull_enum(g, cfg)
        rg, emask = pe.rg, pe.emask
    else:
        rg, emask = None, None
    labels_out, is_pull, m_f, active_dev, st_dev = _directed_round_jit(
        g, rg, emask, values, labels, frontier, cfg=cfg, op=op,
        pull_op=pull_op, collect_stats=collect_stats)
    st = active = None
    if collect_stats or return_active:
        # ONE blocking sync for everything the host loop observes
        is_pull_h, m_f_h, active, st_h = jax.device_get(
            (is_pull, m_f, active_dev, st_dev))
        _note_host_transfer()
        active = np.atleast_1d(active)
        if collect_stats:
            st = RoundStats.from_device(st_h)._replace(
                direction="pull" if bool(is_pull_h) else "push",
                frontier_edges=int(m_f_h), host_transfers=1)
    labels_out = labels_out if batched else labels_out[0]
    result = (labels_out, st)
    return result + (active,) if return_active else result
