"""``dtype-narrowing``: narrow ``.astype`` in core/ must be a
declared-safe wire narrowing.

The wire codec layer (``core/wire.py``, DESIGN.md section 14) ships
sync payloads in narrow dtypes only where an operator *declares* the
narrowing exact for its combine
(:attr:`repro.core.operators.Operator.wire_narrow`).  A narrow
``.astype`` anywhere else in ``core/`` is how silent precision loss
enters a label path — an int32 hop count squeezed through ``uint8``
truncates without any error.  This pass parses the ``wire_narrow=``
declarations from ``operators.py`` *statically* (AST only — the
linter never imports jax) and flags every ``.astype`` in ``core/``
whose statically-known target dtype is narrower than 32 bits and not
in the declared union.  Dynamically-chosen dtypes
(``.astype(some_var)``) are the codec layer's own dispatch and cannot
be resolved statically; they are not flagged.  Justified exceptions
carry a pragma: ``# repro: allow[dtype-narrowing] -- why``.
"""
from __future__ import annotations

import ast
import os
from typing import FrozenSet, List

from ..findings import Finding
from ..registry import Rule, register_rule

RULE_ID = "dtype-narrowing"

DECLARATION_KEYWORD = "wire_narrow"

#: dtype names narrower than the 32-bit label/payload word
NARROW_NAMES: FrozenSet[str] = frozenset({
    "int8", "uint8", "int16", "uint16", "float16", "bfloat16"})


def _parse_declarations(source: str) -> FrozenSet[str]:
    """The union of every ``wire_narrow=("...", ...)`` literal tuple
    passed to an ``Operator(...)`` call in operators.py."""
    declared: set = set()
    for node in ast.walk(ast.parse(source)):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg != DECLARATION_KEYWORD:
                continue
            if isinstance(kw.value, (ast.Tuple, ast.List, ast.Set)):
                for el in kw.value.elts:
                    if isinstance(el, ast.Constant) \
                            and isinstance(el.value, str):
                        declared.add(el.value)
    return frozenset(declared)


def _declared_narrowings(ctx) -> FrozenSet[str]:
    """Locate and parse the nearest ``operators.py`` (cached per
    directory in the session); no registry found means NO narrowing
    is declared safe."""
    d = os.path.dirname(ctx.path)
    key = ("wire-narrow-registry", d)
    if key in ctx.session.memo:
        return ctx.session.memo[key]
    declared: FrozenSet[str] = frozenset()
    for rel in ("operators.py",
                os.path.join("..", "core", "operators.py"),
                os.path.join("..", "operators.py")):
        cand = os.path.normpath(os.path.join(d, rel))
        if os.path.isfile(cand):
            with open(cand, "r", encoding="utf-8") as fh:
                declared = _parse_declarations(fh.read())
            break
    ctx.session.memo[key] = declared
    return declared


def _static_dtype_name(node) -> str | None:
    """The dtype name of an ``.astype`` argument when statically
    resolvable: ``jnp.uint16`` / ``np.int8`` attributes, ``"uint16"``
    string constants, or bare ``uint16`` names."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def check(ctx) -> List[Finding]:
    """Run the dtype-narrowing pass over one core/ file."""
    if not ctx.in_dir("core"):
        return []
    declared = _declared_narrowings(ctx)
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if not (isinstance(func, ast.Attribute)
                and func.attr == "astype" and node.args):
            continue
        name = _static_dtype_name(node.args[0])
        if name is None or name not in NARROW_NAMES:
            continue
        if name in declared:
            continue
        out.append(ctx.finding(
            node, RULE_ID,
            f"`.astype({name})` narrows below the 32-bit payload "
            f"word but {name!r} is not in any operator's declared "
            f"safe-narrowing set ({DECLARATION_KEYWORD}= in "
            f"operators.py) — silent truncation on a label path"))
    return out


register_rule(Rule(
    id=RULE_ID,
    description="narrow .astype in core/ must be a wire_narrow-"
                "declared safe narrowing from operators.py",
    check=check,
))
