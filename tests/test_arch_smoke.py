"""Per-architecture smoke tests: reduced config, one forward + one
train step + one prefill/decode step on CPU; asserts shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import transformer as T
from repro.train.steps import make_train_step, init_train_state
from repro.optim import OptConfig

B, S = 2, 32


def _batch(cfg, key):
    shape = ((B, S) if cfg.num_codebooks == 1
             else (B, S, cfg.num_codebooks))
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size, jnp.int32)
    out = {"tokens": tokens, "labels": tokens}
    if cfg.prefix_len:
        out["prefix_emb"] = jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = T.forward(params, cfg, batch["tokens"],
                            prefix_emb=batch.get("prefix_emb"),
                            remat=False)
    total_s = S + cfg.prefix_len
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, total_s, cfg.num_codebooks,
                                cfg.padded_vocab)
    else:
        assert logits.shape == (B, total_s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    cfg = get_smoke_config(arch)
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3)))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    losses = []
    for _ in range(4):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch):
    cfg = get_smoke_config(arch)
    if cfg.prefix_len:
        pytest.skip("vlm decode exercised via backbone twin archs")
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    max_len = S + 4
    cache = T.zeros_cache(cfg, B, max_len)
    shape = ((B, S) if cfg.num_codebooks == 1
             else (B, S, cfg.num_codebooks))
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size, jnp.int32)
    logits, cache = T.prefill(params, cfg, tokens, cache)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok_shape = ((B, 1) if cfg.num_codebooks == 1
                 else (B, 1, cfg.num_codebooks))
    tok = jnp.zeros(tok_shape, jnp.int32)
    for _ in range(2):
        logits, cache = T.decode_step(params, cfg, tok, cache)
        assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["index"]) == S + 2


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-2.7b",
                                  "zamba2-2.7b", "minicpm3-4b"])
def test_prefill_decode_matches_forward(arch):
    """Incremental decoding must agree with the parallel forward pass."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    full_logits, _ = T.forward(params, cfg, tokens, remat=False)

    cache = T.zeros_cache(cfg, B, S)
    pre, cache = T.prefill(params, cfg, tokens[:, :S - 2], cache)
    np.testing.assert_allclose(np.asarray(pre[:, 0]),
                               np.asarray(full_logits[:, S - 3]),
                               rtol=2e-2, atol=2e-2)
    l1, cache = T.decode_step(params, cfg, tokens[:, S - 2:S - 1], cache)
    np.testing.assert_allclose(np.asarray(l1[:, 0]),
                               np.asarray(full_logits[:, S - 2]),
                               rtol=2e-2, atol=2e-2)
    l2, cache = T.decode_step(params, cfg, tokens[:, S - 1:], cache)
    np.testing.assert_allclose(np.asarray(l2[:, 0]),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-2, atol=2e-2)
