"""Shared ``ast`` helpers for the lint rules.

The interesting piece is :func:`collect_jit_bindings`: the repo
applies ``jax.jit`` three ways —

* decorator: ``@jax.jit`` / ``@partial(jax.jit, static_argnames=...)``
* module-level partial application:
  ``name = partial(jax.jit, static_argnames=(...))(impl_fn)``
* direct call: ``name = jax.jit(impl_fn, static_argnames=...)``

— plus Pallas kernels referenced by ``pl.pallas_call(kernel, ...)``.
All four resolve (when the target is a def in the same module) to a
:class:`JitBinding` carrying the traced function and its literal
``static_argnames``, which is what the jit-purity and
static-argnames-drift rules consume.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

#: attribute accesses on a traced array that yield *static* metadata —
#: branching on these is trace-safe (``if labels.ndim == 2:``)
STATIC_ATTRS = {"ndim", "shape", "dtype", "size", "itemsize"}

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"partial", "functools.partial"}


def dotted(node: ast.AST) -> Optional[str]:
    """Dotted name of a ``Name``/``Attribute`` chain, else ``None``.

    ``jnp.any`` -> ``"jnp.any"``; anything with a non-name base
    (calls, subscripts) -> ``None``.
    """
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def contains_jnp(node: ast.AST) -> bool:
    """Whether the expression references ``jnp.*`` / ``jax.numpy.*``
    (i.e. syntactically produces or consumes a device array)."""
    for sub in ast.walk(node):
        d = dotted(sub)
        if d and (d == "jnp" or d.startswith("jnp.")
                  or d.startswith("jax.numpy.")):
            return True
    return False


def is_none_comparison(node: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` — static structure checks
    that are safe on traced values (``None`` is never a tracer)."""
    return (isinstance(node, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot))
                    for op in node.ops))


def references_names(node: ast.AST, names: Set[str]) -> bool:
    """Whether ``node`` reads any of ``names`` in a *traced* position.

    Reads reached only through a static-metadata attribute
    (``x.ndim``, ``x.shape``...) or an ``is None`` comparison do not
    count: those are trace-safe.
    """
    if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
        return False
    if is_none_comparison(node):
        return False
    if (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "len"):
        return False  # len() of anything is static Python
    if isinstance(node, ast.Name):
        return node.id in names
    return any(references_names(child, names)
               for child in ast.iter_child_nodes(node))


@dataclasses.dataclass
class JitBinding:
    """One site where a function is handed to ``jax.jit`` (or
    ``pallas_call``), resolved as far as the AST allows."""

    func: Optional[ast.AST]
    """The traced ``FunctionDef``, if defined in this module."""

    func_name: Optional[str]
    """Name the target was referenced by (for messages)."""

    static_names: Optional[Tuple[str, ...]]
    """Literal ``static_argnames``; ``()`` if none given, ``None`` if
    present but not a string/tuple literal (unresolvable)."""

    static_node: Optional[ast.AST]
    """The ``static_argnames=`` value node (for finding locations)."""

    lineno: int
    """Line of the jit application itself."""

    kind: str = "jit"
    """``"jit"`` or ``"pallas"``."""


def _is_jit_ref(node: ast.AST) -> bool:
    return dotted(node) in _JIT_NAMES


def _is_partial_ref(node: ast.AST) -> bool:
    return dotted(node) in _PARTIAL_NAMES


def _literal_static_names(node: ast.AST):
    """Parse a ``static_argnames=`` value: a string constant or a
    tuple/list of them.  Returns ``None`` when non-literal."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        names = []
        for el in node.elts:
            if not (isinstance(el, ast.Constant)
                    and isinstance(el.value, str)):
                return None
            names.append(el.value)
        return tuple(names)
    return None


def _static_kwarg(call: ast.Call):
    """The ``static_argnames`` keyword of ``call``, if any."""
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            return kw.value
    return None


def _partial_jit_call(node: ast.AST) -> Optional[ast.Call]:
    """Match ``partial(jax.jit, ...)`` and return the Call."""
    if (isinstance(node, ast.Call) and _is_partial_ref(node.func)
            and node.args and _is_jit_ref(node.args[0])):
        return node
    return None


def _defs_by_name(tree: ast.AST) -> Dict[str, ast.AST]:
    """Module- and class-level function defs, by name."""
    defs: Dict[str, ast.AST] = {}
    blocks = [tree.body]
    for stmt in tree.body:
        if isinstance(stmt, ast.ClassDef):
            blocks.append(stmt.body)
    for block in blocks:
        for stmt in block:
            if isinstance(stmt, (ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
                defs[stmt.name] = stmt
    return defs


def _partial_bindings(tree: ast.AST) -> Dict[str, tuple]:
    """``name -> (target_def_name, bound_kwarg_names)`` for every
    ``name = partial(fn, kw=...)`` assignment anywhere in the module —
    the kernels' idiom for binding static parameters before handing
    the rest to ``pallas_call``."""
    out: Dict[str, tuple] = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)
                and _is_partial_ref(node.value.func)
                and node.value.args
                and isinstance(node.value.args[0], ast.Name)):
            continue
        kw_names = tuple(kw.arg for kw in node.value.keywords
                         if kw.arg is not None)
        out[node.targets[0].id] = (node.value.args[0].id, kw_names)
    return out


def collect_jit_bindings(tree: ast.AST) -> List[JitBinding]:
    """Every jit/pallas tracing site in the module (see module doc)."""
    defs = _defs_by_name(tree)
    partials = _partial_bindings(tree)
    bindings: List[JitBinding] = []

    def add(func, func_name, call: Optional[ast.Call], lineno,
            kind="jit"):
        static_node = _static_kwarg(call) if call is not None else None
        if static_node is None:
            statics: Optional[Tuple[str, ...]] = ()
        else:
            statics = _literal_static_names(static_node)
        bindings.append(JitBinding(
            func=func, func_name=func_name, static_names=statics,
            static_node=static_node, lineno=lineno, kind=kind))

    # decorator forms
    for name, fn in defs.items():
        for dec in fn.decorator_list:
            if _is_jit_ref(dec):
                add(fn, name, None, dec.lineno)
            elif isinstance(dec, ast.Call):
                pj = _partial_jit_call(dec)
                if pj is not None:
                    add(fn, name, pj, dec.lineno)
                elif _is_jit_ref(dec.func):
                    add(fn, name, dec, dec.lineno)

    # call forms anywhere in the module
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        target: Optional[ast.AST] = None
        call_with_statics: Optional[ast.Call] = None
        kind = "jit"
        pj = _partial_jit_call(node.func) if isinstance(
            node.func, ast.Call) else None
        if pj is not None and node.args:
            # partial(jax.jit, ...)(impl)
            target = node.args[0]
            call_with_statics = pj
        elif _is_jit_ref(node.func) and node.args:
            # jax.jit(impl, static_argnames=...)
            target = node.args[0]
            call_with_statics = node
        elif (dotted(node.func) or "").endswith("pallas_call") \
                and node.args:
            target = node.args[0]
            kind = "pallas"
        if target is None or not isinstance(target, ast.Name):
            continue
        fn = defs.get(target.id)
        if fn is not None:
            add(fn, target.id, call_with_statics, node.lineno, kind)
        elif kind == "pallas" and target.id in partials:
            # pallas_call(kern) where kern = partial(_kernel, kw=...):
            # the partially-bound kwargs are the kernel's static params
            impl_name, kw_names = partials[target.id]
            impl = defs.get(impl_name)
            if impl is not None:
                bindings.append(JitBinding(
                    func=impl, func_name=impl_name,
                    static_names=kw_names, static_node=None,
                    lineno=node.lineno, kind=kind))
    return bindings


def param_names(fn: ast.AST) -> List[str]:
    """All parameter names of a function def, in order."""
    a = fn.args
    params = [p.arg for p in
              getattr(a, "posonlyargs", []) + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return params


def assigned_names(node: ast.AST) -> Set[str]:
    """Names bound anywhere inside ``node`` (assignments, loop and
    ``with`` targets, comprehensions, local defs)."""
    out: Set[str] = set()

    def targets_of(t):
        # only true bindings: a subscript/attribute store mutates an
        # existing object, it does not bind the root name
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                targets_of(el)
        elif isinstance(t, ast.Starred):
            targets_of(t.value)

    for sub in ast.walk(node):
        if isinstance(sub, ast.Assign):
            for t in sub.targets:
                targets_of(t)
        elif isinstance(sub, (ast.AugAssign, ast.AnnAssign,
                              ast.For, ast.AsyncFor)):
            targets_of(sub.target)
        elif isinstance(sub, ast.comprehension):
            targets_of(sub.target)
        elif isinstance(sub, (ast.With, ast.AsyncWith)):
            for item in sub.items:
                if item.optional_vars is not None:
                    targets_of(item.optional_vars)
        elif isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
            out.add(sub.name)
    return out


def module_level_names(tree: ast.AST) -> Set[str]:
    """Names assigned at module top level (mutable-global candidates)."""
    out: Set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name):
                        out.add(sub.id)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            if isinstance(stmt.target, ast.Name):
                out.add(stmt.target.id)
    return out


def root_name(node: ast.AST) -> Optional[str]:
    """Leftmost ``Name`` of an attribute/subscript chain."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None
