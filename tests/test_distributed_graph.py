"""Distributed (Gluon-analog) runtime: multi-device BSP correctness.

Runs in a subprocess so the forced host device count never leaks into
other tests (smoke tests must see 1 device).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import graph as G
from repro.core.partition import partition, partition_stats
from repro.core import gluon
from repro.core.balancer import BalancerConfig
from repro.core.apps import sssp, pagerank

assert len(jax.devices()) == 4, jax.devices()
g = G.rmat(9, 8, seed=5)
src = G.highest_out_degree_vertex(g)
ref = sssp(g, src, BalancerConfig(strategy="alb", threshold=64))
mesh = gluon.device_mesh(4)
for policy in ["oec", "iec", "cvc"]:
    sg, meta = partition(g, 4, policy)
    for sync in ["replicated", "mirror"]:
        labels, rounds, secs = gluon.sssp_distributed(
            sg, mesh, src, BalancerConfig(strategy="alb", threshold=64),
            sync=sync, meta=meta)
        assert np.array_equal(np.asarray(labels), np.asarray(ref.labels)), \
            (policy, sync)
    st = partition_stats(sg, meta)
    assert st["imbalance"] < 2.0, (policy, st)
    assert st["replication_factor"] >= 1.0, (policy, st)

rg = G.reverse_graph(g)
srg, rmeta = partition(rg, 4, "oec")
pref = pagerank(g, max_rounds=30, tol=0.0)
for sync in ["replicated", "mirror"]:
    rank, rounds, secs = gluon.pagerank_distributed(
        srg, mesh, g.out_degrees(), max_rounds=30, tol=0.0,
        sync=sync, meta=rmeta)
    assert np.allclose(np.asarray(rank), np.asarray(pref.labels),
                       atol=1e-6), sync
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_distributed_apps_match_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DISTRIBUTED_OK" in out.stdout
