"""Push vs pull vs adaptive traversal direction (DESIGN.md section 9).

The ALB picks a load-balancing *strategy* per round from the fused
host counts; the direction planner reuses the same counts to pick the
traversal *direction* (Beamer-style): dense frontiers run as a pull
over the cached reverse CSR, sparse frontiers as the ordinary push.
This harness sweeps bfs/sssp over the paper's graph classes with
``direction`` in {push, pull, adaptive} and reports wall clock, round
counts, and the share of rounds adaptive ran as pulls.

Rows: ``dir_<app>_<graph>_<direction>,us_per_run,rounds=N pull_share=S``.

Run directly (also wired as the ``direction`` selector of
benchmarks.run):

    PYTHONPATH=src python -m benchmarks.fig_direction          # sweep
    PYTHONPATH=src python -m benchmarks.fig_direction --smoke  # CI

``--smoke`` shrinks the input and gates on STRUCTURAL invariants only
(CI boxes are noisy timers — wall clock is reported, never asserted):

1. parity — pull and adaptive labels are bitwise equal to push;
2. trace — adaptive's recorded per-round direction equals
   :func:`repro.core.balancer.resolve_direction` replayed over the
   recorded per-round counts;
3. rounds — adaptive's round count never exceeds push-only's.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import graph as G
from repro.core.apps import bfs, sssp
from repro.core.balancer import BalancerConfig, resolve_direction

from .common import timed, emit

DIRECTIONS = ["push", "pull", "adaptive"]


def _inputs(smoke: bool) -> dict:
    if smoke:
        return {"rmat": G.rmat(9, 8, seed=1),
                "road": G.road_grid(16, seed=1)}
    return {"rmat": G.rmat(12, 16, seed=1),
            "road": G.road_grid(64, seed=1)}


def run(smoke: bool = False) -> int:
    cfg = BalancerConfig(strategy="alb", threshold=64)
    apps = {"bfs": bfs} if smoke else {"bfs": bfs, "sssp": sssp}
    failures = 0
    for gname, g in _inputs(smoke).items():
        src = G.highest_out_degree_vertex(g)
        v, e = g.num_vertices, g.num_edges
        for app_name, driver in apps.items():
            results = {}
            for direction in DIRECTIONS:
                out = driver(g, src, cfg, direction=direction,
                             collect_stats=True)
                secs = timed(lambda d=direction: driver(g, src, cfg,
                                                        direction=d))
                pulls = sum(st.direction == "pull" for st in out.stats)
                share = pulls / max(len(out.stats), 1)
                emit(f"dir_{app_name}_{gname}_{direction}", secs,
                     f"rounds={out.rounds} pull_share={share:.2f}")
                results[direction] = out
            # ---- structural gates (deterministic; no wall clock) ----
            push, ad = results["push"], results["adaptive"]
            for direction in ("pull", "adaptive"):
                if not np.array_equal(
                        np.asarray(results[direction].labels),
                        np.asarray(push.labels)):
                    print(f"FAIL: {app_name}/{gname}: {direction} "
                          f"labels != push labels", file=sys.stderr)
                    failures += 1
            acfg = BalancerConfig(strategy="alb", threshold=64,
                                  direction="adaptive")
            for i, st in enumerate(ad.stats):
                want = resolve_direction(acfg, st.frontier_size,
                                         st.frontier_edges, v, e)
                if st.direction != want:
                    print(f"FAIL: {app_name}/{gname} round {i}: ran "
                          f"{st.direction}, threshold rule says {want}",
                          file=sys.stderr)
                    failures += 1
            if ad.rounds > push.rounds:
                print(f"FAIL: {app_name}/{gname}: adaptive took "
                      f"{ad.rounds} rounds > push's {push.rounds}",
                      file=sys.stderr)
                failures += 1
    return failures


def main() -> int:
    smoke = "--smoke" in sys.argv[1:]
    failures = run(smoke=smoke)
    if failures:
        return 1
    if smoke:
        print("smoke OK: direction parity + adaptive trace + rounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
