from .drivers import bfs, sssp, cc, pagerank, kcore, AppResult

__all__ = ["bfs", "sssp", "cc", "pagerank", "kcore", "AppResult"]
