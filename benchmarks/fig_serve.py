"""Serving throughput + latency: continuous batching vs static batches
(DESIGN.md section 8).

The static batched engine (fig_qps.py) restarts its loop per batch and
pays max-over-batch rounds every time: a batch is only as fast as its
deepest member, and tail rounds run with mostly-empty slots.  The
continuous-batching service (``repro.serve``) retires a converged row
immediately and refills it mid-loop, so slots stay occupied while the
queue has work.  This harness measures both effects:

* **Throughput** (saturated arrivals): wall-clock queries/sec of the
  full ``QueryService`` vs the restart-per-batch baseline on a
  repeat-heavy (Zipf-over-sources) rmat workload — the traffic shape
  a deployment actually sees, where the service's LRU result cache
  answers repeats without touching the device while the baseline
  recomputes them.  A distinct-source, cache-off pairing is emitted
  alongside (``serve_qps_nocache_*``) to isolate the
  continuous-batching effect from the cache.
* **Packing** (deterministic): total service rounds of cache-off
  continuous serving vs the baseline's sum of max-over-batch rounds —
  the fill-the-idle-lanes advantage, independent of timer noise.
* **Latency vs load** (Poisson arrivals): p50/p95 rounds-in-system and
  slot occupancy as the arrival rate (queries/round) sweeps from idle
  to saturated — the latency/utilization tradeoff a deployment tunes.

Rows: ``serve_qps_{continuous|static}_b<B>``, ``serve_cached_b<B>``
(derived: hit rate), ``serve_qps_nocache_{continuous|static}_b<B>``,
``serve_steps_b<B>``, ``serve_transfers_b<B>`` (derived: blocking
host transfers, host vs fused stepping), ``serve_poisson_r<rate>``
(derived: p50/p95/occupancy).

Run directly (also the ``serve`` selector of benchmarks.run):

    PYTHONPATH=src python -m benchmarks.fig_serve          # full
    PYTHONPATH=src python -m benchmarks.fig_serve --smoke  # CI gate

``--smoke`` shrinks the input and exits non-zero unless (a) service
queries/sec on the Zipf workload >= the static-batch baseline and
(b) cache-off continuous serving needs no more rounds than the
baseline, and (c) fused-mode serving (DESIGN.md section 11) pays
strictly fewer blocking host transfers than host-mode stepping —
the acceptance gates for the serving layer.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import graph as G
from repro.core.apps import bfs_batch, sssp_batch
from repro.core.balancer import BalancerConfig
from repro.serve import QueryService

from .common import emit, pick_sources

_BATCH = {"bfs": bfs_batch, "sssp": sssp_batch}


def _traffic(sources: list, n: int, seed: int = 7) -> list:
    """n submissions Zipf-distributed over the distinct ``sources``:
    real query traffic repeats popular sources (the service's result
    cache exists for exactly this shape).  Deterministic under
    ``seed``; every distinct source appears at least once."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(sources) + 1, dtype=np.float64)
    p = (1.0 / ranks) / np.sum(1.0 / ranks)
    picks = list(rng.choice(len(sources), size=n - len(sources), p=p))
    order = list(rng.permutation(len(sources))) + picks
    return [sources[i] for i in order]


def _serve_all(g, sources, cfg, b, app="sssp", cache_capacity=0,
               mode="host"):
    """Saturated continuous serving: submit everything, drain."""
    svc = QueryService(num_slots=b, cfg=cfg,
                       cache_capacity=cache_capacity, mode=mode)
    svc.register_graph("g", g)
    for s in sources:
        svc.submit("g", app, s)
    svc.run()
    return svc


def _static_batches(g, sources, cfg, b, app="sssp"):
    """Restart-per-batch baseline: group the FIFO into chunks of B and
    run each batch to completion before starting the next.  Results
    are copied to the host — a service delivers host labels, so both
    sides pay for publication."""
    for i in range(0, len(sources), b):
        np.asarray(_BATCH[app](g, sources[i:i + b], cfg).labels)


def _poisson_serve(g, sources, cfg, b, rate, app="sssp", seed=0):
    """Open-loop arrivals: each service round admits Poisson(rate) new
    queries from the workload until it is exhausted, then drains."""
    svc = QueryService(num_slots=b, cfg=cfg, cache_capacity=0)
    svc.register_graph("g", g)
    rng = np.random.default_rng(seed)
    i = 0
    while True:
        for _ in range(int(rng.poisson(rate))):
            if i < len(sources):
                svc.submit("g", app, sources[i])
                i += 1
        worked = svc.step()
        if i >= len(sources) and not worked:
            return svc


def _paired(fn_a, fn_b, repeats: int = 5):
    """Interleaved median-of-N of two competitors: alternating the
    measurements cancels the slow machine-load drift that would bias
    two back-to-back ``timed`` calls on a shared CI box."""
    import time
    fn_a(), fn_b()                          # warmup (compilation)
    ta, tb = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)), float(np.median(tb))


def _static_rounds(g, sources, cfg, b, app="sssp") -> int:
    """Total rounds the restart-per-batch baseline executes: each batch
    costs max-over-members rounds (a batch is only as fast as its
    deepest query)."""
    return sum(_BATCH[app](g, sources[i:i + b], cfg).rounds
               for i in range(0, len(sources), b))


def run(smoke: bool = False) -> dict:
    scale = 9 if smoke else 12
    b = 8
    n_distinct = 12 if smoke else 32
    n_queries = 24 if smoke else 96
    g = G.rmat(scale, 8 if smoke else 16, seed=1)
    cfg = BalancerConfig(strategy="alb", threshold=64)
    distinct = pick_sources(g, n_distinct)
    traffic = _traffic(distinct, n_queries)
    results: dict = {}

    # ---- throughput on Zipf traffic: service (cache on) vs restart ----
    # repeats hit the service's LRU cache without touching the device;
    # the restart-per-batch baseline recomputes every submission
    secs_c, secs_s = _paired(
        lambda: _serve_all(g, traffic, cfg, b,
                           cache_capacity=n_queries),
        lambda: _static_batches(g, traffic, cfg, b),
        repeats=3 if smoke else 5)
    qps_c, qps_s = n_queries / secs_c, n_queries / secs_s
    results["qps_continuous"], results["qps_static"] = qps_c, qps_s
    emit(f"serve_qps_continuous_b{b}", secs_c, f"qps={qps_c:.1f}")
    emit(f"serve_qps_static_b{b}", secs_s, f"qps={qps_s:.1f}")
    svc = _serve_all(g, traffic, cfg, b, cache_capacity=n_queries)
    results["cache_hit_rate"] = svc.stats.cache_hit_rate
    emit(f"serve_cached_b{b}", 0.0,
         f"hit_rate={svc.stats.cache_hit_rate:.2f}")

    # ---- isolate continuous batching: distinct sources, cache off ----
    secs_nc, secs_ns = _paired(
        lambda: _serve_all(g, distinct, cfg, b),
        lambda: _static_batches(g, distinct, cfg, b),
        repeats=3 if smoke else 5)
    emit(f"serve_qps_nocache_continuous_b{b}", secs_nc,
         f"qps={n_distinct / secs_nc:.1f}")
    emit(f"serve_qps_nocache_static_b{b}", secs_ns,
         f"qps={n_distinct / secs_ns:.1f}")

    # ---- deterministic packing: rounds, not timers -------------------
    svc = _serve_all(g, distinct, cfg, b)
    steps_c = svc.stats.steps
    rounds_s = _static_rounds(g, distinct, cfg, b)
    results["steps_continuous"] = steps_c
    results["rounds_static"] = rounds_s
    emit(f"serve_steps_b{b}", 0.0,
         f"continuous={steps_c};static={rounds_s};"
         f"occupancy={svc.stats.occupancy:.3f}")

    # ---- fused stepping: sync points, not timers ---------------------
    # the fused engine runs chunks of fused_rounds balancer rounds per
    # service step inside one lax.while_loop, paying one blocking
    # observation per chunk instead of one per round (DESIGN.md
    # section 11); deterministic — labels are bitwise those of host
    # stepping, so only the transfer counts differ
    svcf = _serve_all(g, distinct, cfg, b, mode="fused")
    results["summary_host"] = svc.stats.summary()
    results["summary_fused"] = svcf.stats.summary()
    emit(f"serve_transfers_b{b}", 0.0,
         f"host={svc.stats.host_transfers};"
         f"fused={svcf.stats.host_transfers};"
         f"fused_steps={svcf.stats.steps}")

    # ---- latency vs Poisson arrival rate ------------------------------
    rates = [0.5, 2.0] if smoke else [0.25, 0.5, 1.0, 2.0, 4.0]
    for rate in rates:
        svc = _poisson_serve(g, distinct, cfg, b, rate)
        st = svc.stats
        results[f"poisson_{rate}"] = st.summary()
        emit(f"serve_poisson_r{rate}", 0.0,
             f"p50={st.latency_percentile(50):.0f};"
             f"p95={st.latency_percentile(95):.0f};"
             f"occupancy={st.occupancy:.3f}")
    return results


def main() -> int:
    smoke = "--smoke" in sys.argv[1:]
    results = run(smoke=smoke)
    if smoke:
        qc, qs = results["qps_continuous"], results["qps_static"]
        ok = True
        if qc < qs:
            print(f"FAIL: service ({qc:.1f} qps) slower than the "
                  f"static-batch baseline ({qs:.1f} qps) on the Zipf "
                  f"workload", file=sys.stderr)
            ok = False
        sc, rs = results["steps_continuous"], results["rounds_static"]
        if sc > rs:
            print(f"FAIL: continuous serving took {sc} rounds vs the "
                  f"baseline's {rs} (slot packing regressed)",
                  file=sys.stderr)
            ok = False
        ht_h = results["summary_host"]["host_transfers"]
        ht_f = results["summary_fused"]["host_transfers"]
        if ht_f >= ht_h:
            print(f"FAIL: fused serving paid {ht_f} host transfers vs "
                  f"host stepping's {ht_h} (chunked fused stepping "
                  f"should amortize sync points)", file=sys.stderr)
            ok = False
        if not ok:
            return 1
        print(f"smoke OK: service {qc:.1f} qps >= static {qs:.1f} qps; "
              f"rounds {sc} <= {rs}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
