"""jit'd wrappers: Pallas mapping kernels + XLA gather/scatter epilogue.

These are the ``use_pallas=True`` implementations of the two hot paths
in core/balancer.py.  The mapping (searchsorted / tile expansion) runs
in the Pallas kernel; the irregular HBM traffic (col_idx gather,
scatter-combine into labels) runs in XLA, which lowers it to native TPU
gather/scatter — see edge_lb.py for the design rationale.

Each path ships two entries, registered with the executor registry in
core/balancer.py (DESIGN.md section 3):

* ``twc_bin_apply`` / ``edge_lb_apply`` — host-driven entries: top-level
  jitted, shapes are the per-round *bucketed* capacities chosen by
  ``relax``; one compilation per bucket.
* ``twc_bin_apply_static`` / ``edge_lb_apply_static`` — fully-jit
  entries for ``relax_spmd``: plain functions meant to be traced inside
  an enclosing ``jit``/``shard_map``; capacities are static (V for the
  bins, E for the LB span), the chunk index is a traced scalar so a
  ``lax.while_loop`` can drive unbounded bins.

All entries are **batched** (DESIGN.md section 7): ``values`` /
``labels`` / ``fmask`` carry a leading query axis ``[B, V]`` while the
vertex/edge enumeration stays batch-shared.  The mapping kernel
therefore runs ONCE per round for the whole batch — it emits the
(graph_edge, anchor/slot, mask) tiles of the union frontier — and the
XLA epilogue re-gathers per-query values / activity from the ``[B, V]``
arrays before the batched scatter-combine.  (The kernel's own value
output is only a single query's view and is ignored here.)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

# balancer imports this module only lazily (get_executor), so sharing
# its batched scatter-combine epilogue creates no import cycle — both
# backends neutralize inactive (vertex, query) slots with the exact
# same combiner-identity rule (balancer.combine_neutral)
from repro.core.balancer import _apply

from . import edge_lb as _edge_lb
from . import merge_path as _merge_path
from . import twc_gather as _twc


# ---------------------------------------------------------------------------
# LB executor (edge-balanced renumbering)
# ---------------------------------------------------------------------------

def edge_lb_apply_static(g, values, labels, fmask, hvidx, hdeg, hrow,
                         total, ecap: int, op, distribution: str,
                         num_tiles: int, tile_edges: int):
    """Fully-jit LB entry: trace-safe body (no own jit wrapper)."""
    v = labels.shape[-1]
    start_e = jnp.cumsum(hdeg) - hdeg
    vsafe = jnp.where(hvidx < v, hvidx, 0)
    hval = values[0, vsafe]            # kernel value plumbing: batch 0
    ge, j, _, mask = _edge_lb.edge_lb_map(
        start_e, hrow, hval, total, ecap,
        tile_edges=tile_edges, distribution=distribution,
        num_tiles=num_tiles)
    dst = g.col_idx[ge]
    w = g.edge_w[ge]
    j = jnp.clip(j, 0, hvidx.shape[0] - 1)
    src = jnp.where(hvidx.shape[0] > 0, hvidx[j], 0)
    ssafe = jnp.where(src < v, src, 0)
    if op.direction == "push":
        live = fmask[:, ssafe]                           # [B, n]
        cand = op.msg(values[:, ssafe], w[None])
        return _apply(labels, dst, cand, mask, live, op.combine)
    # pull: value AND activity gathered at the in-neighbour (``dst`` in
    # the reverse CSR), combined at the anchor (DESIGN.md section 9)
    live = fmask[:, dst]                                 # [B, n]
    cand = op.msg(values[:, dst], w[None])
    return _apply(labels, src, cand, mask, live, op.combine)


@partial(jax.jit,
         static_argnames=("ecap", "op", "distribution", "num_tiles",
                          "tile_edges"))
def edge_lb_apply(g, values, labels, fmask, hvidx, hdeg, hrow, total,
                  ecap: int, op, distribution: str, num_tiles: int,
                  tile_edges: int):
    """Host-driven LB entry: jitted per (ecap, op, ...) bucket."""
    return edge_lb_apply_static(g, values, labels, fmask, hvidx, hdeg,
                                hrow, total, ecap, op, distribution,
                                num_tiles, tile_edges)


# ---------------------------------------------------------------------------
# Merge-path executor (equal-work edge tiles, no bins, no inspector)
# ---------------------------------------------------------------------------

def merge_path_apply_static(g, values, labels, fmask, hvidx, hdeg, hrow,
                            total, ecap: int, op, distribution: str,
                            num_tiles: int, tile_edges: int):
    """Fully-jit merge-path entry: trace-safe body (no own jit wrapper).

    Signature-compatible with the LB entries so the executor registry
    can route the whole frontier through it (``effective_plan``
    collapses the plan to LB-all under this backend).  The co-ranked
    equal-work deal is contiguous by construction, so ``distribution``
    and ``num_tiles`` do not apply and are ignored."""
    del distribution, num_tiles
    v = labels.shape[-1]
    start_e = jnp.cumsum(hdeg) - hdeg
    ge, j, mask = _merge_path.merge_path_map(
        start_e, hrow, total, ecap, tile_edges=tile_edges)
    dst = g.col_idx[ge]
    w = g.edge_w[ge]
    j = jnp.clip(j, 0, hvidx.shape[0] - 1)
    src = jnp.where(hvidx.shape[0] > 0, hvidx[j], 0)
    ssafe = jnp.where(src < v, src, 0)
    if op.direction == "push":
        live = fmask[:, ssafe]                           # [B, n]
        cand = op.msg(values[:, ssafe], w[None])
        return _apply(labels, dst, cand, mask, live, op.combine)
    # pull: value AND activity gathered at the in-neighbour (``dst`` in
    # the reverse CSR), combined at the anchor (DESIGN.md section 9)
    live = fmask[:, dst]                                 # [B, n]
    cand = op.msg(values[:, dst], w[None])
    return _apply(labels, src, cand, mask, live, op.combine)


@partial(jax.jit,
         static_argnames=("ecap", "op", "distribution", "num_tiles",
                          "tile_edges"))
def merge_path_apply(g, values, labels, fmask, hvidx, hdeg, hrow, total,
                     ecap: int, op, distribution: str, num_tiles: int,
                     tile_edges: int):
    """Host-driven merge-path entry: jitted per (ecap, op, ...) bucket."""
    return merge_path_apply_static(g, values, labels, fmask, hvidx,
                                   hdeg, hrow, total, ecap, op,
                                   distribution, num_tiles, tile_edges)


def merge_path_no_bins(*_args, **_kwargs):
    """Bin-entry placeholder of the merge-path pair: the backend's plan
    has no degree bins (``effective_plan``), so reaching this is a
    planner bug, not a fallback."""
    raise RuntimeError("merge_path backend plans no degree bins; "
                       "its bin executor entries are unreachable")


# ---------------------------------------------------------------------------
# Bin executor (vertex-binned TWC-analog passes)
# ---------------------------------------------------------------------------

def twc_bin_apply_static(g, values, labels, fmask, bvidx, bdeg, brow,
                         width: int, op, chunk):
    """Fully-jit bin entry: ``chunk`` may be a traced int32 scalar."""
    v = labels.shape[-1]
    vsafe = jnp.where(bvidx < v, bvidx, 0)
    bval = values[0, vsafe]            # kernel value plumbing: batch 0
    ge, anchor, _, mask = _twc.twc_bin_map(
        bvidx, bdeg, brow, bval, width=width, chunk=chunk,
        sentinel=v)
    dst = g.col_idx[ge]
    w = g.edge_w[ge]
    # the kernel may pad the bin to its vertex-tile size: recover the
    # per-row vertex ids from the anchor tiles (rows are constant)
    row_vid = anchor[:, 0]                               # [N] (pad = v)
    rsafe = jnp.where(row_vid < v, row_vid, 0)
    if op.direction == "push":
        live = fmask[:, rsafe][:, :, None]               # [B, N, 1]
        val = values[:, rsafe][:, :, None]               # [B, N, 1]
        cand = op.msg(val, w[None])
        return _apply(labels, dst, cand, mask, live, op.combine)
    # pull: value AND activity gathered at the in-neighbour (``dst`` in
    # the reverse CSR), combined at the anchor (DESIGN.md section 9)
    live = fmask[:, dst]                                 # [B, N, W]
    cand = op.msg(values[:, dst], w[None])
    return _apply(labels, anchor, cand, mask, live, op.combine)


@partial(jax.jit, static_argnames=("width", "op"))
def twc_bin_apply(g, values, labels, fmask, bvidx, bdeg, brow,
                  width: int, op, chunk):
    """Host-driven bin entry: jitted per (width, op) bucket."""
    return twc_bin_apply_static(g, values, labels, fmask, bvidx, bdeg,
                                brow, width, op, chunk)
