"""jit'd wrappers: Pallas mapping kernels + XLA gather/scatter epilogue.

These are the ``use_pallas=True`` implementations of the two hot paths
in core/balancer.py.  The mapping (searchsorted / tile expansion) runs
in the Pallas kernel; the irregular HBM traffic (col_idx gather,
scatter-combine into labels) runs in XLA, which lowers it to native TPU
gather/scatter — see edge_lb.py for the design rationale.

Each path ships two entries, registered with the executor registry in
core/balancer.py (DESIGN.md section 3):

* ``twc_bin_apply`` / ``edge_lb_apply`` — host-driven entries: top-level
  jitted, shapes are the per-round *bucketed* capacities chosen by
  ``relax``; one compilation per bucket.
* ``twc_bin_apply_static`` / ``edge_lb_apply_static`` — fully-jit
  entries for ``relax_spmd``: plain functions meant to be traced inside
  an enclosing ``jit``/``shard_map``; capacities are static (V for the
  bins, E for the LB span), the chunk index is a traced scalar so a
  ``lax.while_loop`` can drive unbounded bins.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import edge_lb as _edge_lb
from . import twc_gather as _twc


def _apply(labels, target, cand, mask, combine):
    v = labels.shape[0]
    tgt = jnp.where(mask, target, v)
    if combine == "min":
        return labels.at[tgt].min(cand.astype(labels.dtype), mode="drop")
    return labels.at[tgt].add(
        jnp.where(mask, cand, 0).astype(labels.dtype), mode="drop")


# ---------------------------------------------------------------------------
# LB executor (edge-balanced renumbering)
# ---------------------------------------------------------------------------

def edge_lb_apply_static(g, values, labels, hvidx, hdeg, hrow, total,
                         ecap: int, op, distribution: str,
                         num_tiles: int, tile_edges: int):
    """Fully-jit LB entry: trace-safe body (no own jit wrapper)."""
    start_e = jnp.cumsum(hdeg) - hdeg
    vsafe = jnp.where(hvidx < values.shape[0], hvidx, 0)
    hval = values[vsafe]
    ge, j, val, mask = _edge_lb.edge_lb_map(
        start_e, hrow, hval, total, ecap,
        tile_edges=tile_edges, distribution=distribution,
        num_tiles=num_tiles)
    dst = g.col_idx[ge]
    w = g.edge_w[ge]
    if op.direction == "push":
        cand = op.msg(val, w)
        return _apply(labels, dst, cand, mask, op.combine)
    src = jnp.where(hvidx.shape[0] > 0,
                    hvidx[jnp.clip(j, 0, hvidx.shape[0] - 1)], 0)
    cand = op.msg(values[dst], w)
    return _apply(labels, src, cand, mask, op.combine)


@partial(jax.jit,
         static_argnames=("ecap", "op", "distribution", "num_tiles",
                          "tile_edges"))
def edge_lb_apply(g, values, labels, hvidx, hdeg, hrow, total, ecap: int,
                  op, distribution: str, num_tiles: int, tile_edges: int):
    """Host-driven LB entry: jitted per (ecap, op, ...) bucket."""
    return edge_lb_apply_static(g, values, labels, hvidx, hdeg, hrow,
                                total, ecap, op, distribution, num_tiles,
                                tile_edges)


# ---------------------------------------------------------------------------
# Bin executor (vertex-binned TWC-analog passes)
# ---------------------------------------------------------------------------

def twc_bin_apply_static(g, values, labels, bvidx, bdeg, brow, width: int,
                         op, chunk):
    """Fully-jit bin entry: ``chunk`` may be a traced int32 scalar."""
    sentinel = labels.shape[0]
    vsafe = jnp.where(bvidx < values.shape[0], bvidx, 0)
    bval = values[vsafe]
    ge, anchor, val, mask = _twc.twc_bin_map(
        bvidx, bdeg, brow, bval, width=width, chunk=chunk,
        sentinel=sentinel)
    dst = g.col_idx[ge]
    w = g.edge_w[ge]
    if op.direction == "push":
        cand = op.msg(val, w)
        return _apply(labels, dst, cand, mask, op.combine)
    cand = op.msg(values[dst], w)
    return _apply(labels, anchor, cand, mask, op.combine)


@partial(jax.jit, static_argnames=("width", "op"))
def twc_bin_apply(g, values, labels, bvidx, bdeg, brow, width: int, op,
                  chunk):
    """Host-driven bin entry: jitted per (width, op) bucket."""
    return twc_bin_apply_static(g, values, labels, bvidx, bdeg, brow,
                                width, op, chunk)
