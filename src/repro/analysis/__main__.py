"""CLI for the invariant linter.

  PYTHONPATH=src python -m repro.analysis --check src/ benchmarks/
  PYTHONPATH=src python -m repro.analysis --check --relaxed tests/
  PYTHONPATH=src python -m repro.analysis --list-rules
  PYTHONPATH=src python -m repro.analysis --write-baseline src/

Exit codes: 0 clean, 1 findings (or baseline hygiene violations),
2 usage error (bad flag or nonexistent path).  Findings print one
per line as ``file:line rule-id message``.

Suppressions, in order of preference:

* fix the code;
* a per-line pragma with a mandatory justification:
  ``# repro: allow[<rule>] -- <why this site is intentional>``;
* a baseline entry in ``analysis-baseline.txt`` (grandfathered legacy
  findings only — never allowed for src/repro/core or
  src/repro/serve, which this tool exists to protect).
"""
from __future__ import annotations

import argparse
import sys

from .baseline import (apply_baseline, load_baseline,
                       protected_violations, render_baseline)
from .linter import analyze_paths
from .registry import get_rules


def _rule_table() -> str:
    lines = ["rules:"]
    for r in get_rules():
        star = " (relaxed profile)" if r.relaxed else ""
        lines.append(f"  {r.id:<20} {r.description}{star}")
    lines.append("")
    lines.append("relaxed profile (--relaxed, for tests/): only the "
                 "rules marked above run")
    return "\n".join(lines)


def main(argv=None) -> int:
    """Parse arguments, lint, report; returns the exit code."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__,
        epilog=_rule_table(),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*",
                    help="files or directories to lint")
    ap.add_argument("--check", action="store_true",
                    help="lint and exit 1 on findings (the default "
                         "action; spelled out for CI clarity)")
    ap.add_argument("--relaxed", action="store_true",
                    help="run only the relaxed-profile rules "
                         "(for tests/)")
    ap.add_argument("--baseline", default="analysis-baseline.txt",
                    help="baseline file of grandfathered findings "
                         "(default: %(default)s; missing file = "
                         "empty baseline)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file entirely")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline "
                         "file and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_rule_table())
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        print("error: no paths given (try `--check src/ "
              "benchmarks/`)", file=sys.stderr)
        return 2

    try:
        findings = analyze_paths(args.paths, relaxed=args.relaxed)
    except FileNotFoundError as e:
        print(f"error: no such file or directory: {e.args[0]}",
              file=sys.stderr)
        return 2

    if args.write_baseline:
        text = render_baseline(findings)
        with open(args.baseline, "w", encoding="utf-8") as fh:
            fh.write(text)
        print(f"wrote {args.baseline} ({len(findings)} entries)")
        return 0

    baseline = (load_baseline(args.baseline)
                if not args.no_baseline else {})
    bad_entries = protected_violations(baseline)
    kept, matched, stale = apply_baseline(findings, baseline)

    for f in kept:
        print(f.format())
    for entry in bad_entries:
        print(f"baseline error: protected path may not be "
              f"grandfathered: {entry}", file=sys.stderr)
    if stale:
        print(f"note: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} matched nothing "
              f"(refresh with --write-baseline)", file=sys.stderr)

    n_rules = len(get_rules(relaxed=args.relaxed))
    if kept or bad_entries:
        print(f"{len(kept)} finding(s) ({matched} baselined) across "
              f"{n_rules} rule(s)", file=sys.stderr)
        return 1
    print(f"OK: 0 findings ({matched} baselined) across "
          f"{n_rules} rule(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
