"""Fig 9 analogue: ALB under different partition policies (IEC / OEC /
CVC) — the paper's point: whatever the partitioner does about
inter-device balance, intra-device thread-block imbalance remains and
ALB fixes it."""
from __future__ import annotations

import os
import subprocess
import sys

NDEV = 4


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{NDEV}").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run([sys.executable, "-m", "benchmarks.fig9_partition",
                        "--inner"], env=env, cwd=root,
                       capture_output=True, text=True, timeout=3600)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr[-3000:])
        raise RuntimeError("fig9 inner run failed")


def inner():
    import time
    from repro.core import graph as G
    from repro.core.partition import partition, partition_stats
    from repro.core import gluon
    from repro.core.balancer import BalancerConfig
    from .common import emit

    g = G.rmat(13, 16, seed=1)
    src = G.highest_out_degree_vertex(g)
    mesh = gluon.device_mesh(NDEV)
    for policy in ["oec", "iec", "cvc"]:
        sg, meta = partition(g, NDEV, policy)
        st = partition_stats(sg, meta)
        for strat in ["twc", "alb"]:
            cfg = BalancerConfig(strategy=strat, threshold=1024)
            for sync in ["replicated", "mirror"]:
                gluon.sssp_distributed(sg, mesh, src, cfg, max_rounds=200,
                                       sync=sync, meta=meta)
                t0 = time.perf_counter()
                gluon.sssp_distributed(sg, mesh, src, cfg, max_rounds=200,
                                       sync=sync, meta=meta)
                secs = time.perf_counter() - t0
                emit(f"fig9/sssp/{policy}/{strat}/{sync}", secs,
                     f"edge_imbalance={st['imbalance']:.2f};"
                     f"replication={st['replication_factor']:.2f}")


if __name__ == "__main__":
    if "--inner" in sys.argv:
        inner()
    else:
        run()
