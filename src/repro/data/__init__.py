from .pipeline import synthetic_batch, SyntheticDataset

__all__ = ["synthetic_batch", "SyntheticDataset"]
