"""``scatter-determinism``: executor scatters need a registered
commutative-associative combine.

Executor code (``core/balancer.py`` and ``kernels/``) scatters edge
contributions with ``.at[idx].add/min/max(...)`` where ``idx``
contains duplicates — every frontier bin maps many edges onto the
same target vertex.  The result is deterministic only when the
combine is order-free, i.e. commutative and associative on the
value domain the apps use.  ``operators.py`` declares exactly which
combines qualify (``COMMUTATIVE_COMBINES``); this pass parses that
registry *statically* (AST only — the linter never imports jax) and
flags any ``.at[...].<combine>(...)`` whose method is unregistered.
``.at[...].set`` with potentially-duplicate targets is flagged too:
last-writer-wins depends on scatter order, so a ``set`` needs a
pragma arguing its indices are unique.
"""
from __future__ import annotations

import ast
import os
from typing import FrozenSet, List

from .. import astutil
from ..findings import Finding
from ..registry import Rule, register_rule

RULE_ID = "scatter-determinism"

REGISTRY_NAME = "COMMUTATIVE_COMBINES"

#: used when no operators.py registry can be located (e.g. fixture
#: trees) — deliberately minimal so the linkage is observable
DEFAULT_COMBINES: FrozenSet[str] = frozenset({"min", "max"})

#: ``.at[...]`` methods that combine (or overwrite) at target indices
_SCATTER_METHODS = {"set", "add", "min", "max", "mul", "multiply",
                    "divide", "power"}


def _parse_registry(source: str) -> FrozenSet[str]:
    """Extract ``COMMUTATIVE_COMBINES`` from operators.py source."""
    tree = ast.parse(source)
    for stmt in tree.body:
        if not isinstance(stmt, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == REGISTRY_NAME
                   for t in stmt.targets):
            continue
        value = stmt.value
        if isinstance(value, ast.Call) and value.args:
            value = value.args[0]  # frozenset({...}) / set((...))
        if isinstance(value, (ast.Set, ast.Tuple, ast.List)):
            names = []
            for el in value.elts:
                if isinstance(el, ast.Constant) \
                        and isinstance(el.value, str):
                    names.append(el.value)
            return frozenset(names)
    return DEFAULT_COMBINES


def _combine_registry(ctx) -> FrozenSet[str]:
    """Locate and parse the nearest ``operators.py`` (cached per
    directory in the session); fall back to the default set."""
    d = os.path.dirname(ctx.path)
    key = ("scatter-registry", d)
    if key in ctx.session.memo:
        return ctx.session.memo[key]
    combines = DEFAULT_COMBINES
    for rel in ("operators.py",
                os.path.join("..", "core", "operators.py"),
                os.path.join("..", "operators.py")):
        cand = os.path.normpath(os.path.join(d, rel))
        if os.path.isfile(cand):
            with open(cand, "r", encoding="utf-8") as fh:
                combines = _parse_registry(fh.read())
            break
    ctx.session.memo[key] = combines
    return combines


def _in_scope(ctx) -> bool:
    path = ctx.path
    return (path.endswith("core/balancer.py")
            or ctx.in_dir("kernels")
            or path.endswith("/balancer.py"))


def check(ctx) -> List[Finding]:
    """Run the scatter-determinism pass over one executor file."""
    if not _in_scope(ctx):
        return []
    combines = _combine_registry(ctx)
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if not (isinstance(func, ast.Attribute)
                and func.attr in _SCATTER_METHODS
                and isinstance(func.value, ast.Subscript)
                and isinstance(func.value.value, ast.Attribute)
                and func.value.value.attr == "at"):
            continue
        if func.attr in combines:
            continue
        out.append(ctx.finding(
            node, RULE_ID,
            f"`.at[...].{func.attr}` scatter: combine "
            f"{func.attr!r} is not registered commutative-"
            f"associative in operators.py ({REGISTRY_NAME}) — "
            f"result depends on scatter order under duplicate "
            f"indices"))
    return out


register_rule(Rule(
    id=RULE_ID,
    description="executor .at[...] scatters must use a combine "
                "registered commutative-associative in operators.py",
    check=check,
))
