"""Benchmark aggregator: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run table2 fig8 # subset

Selectors and what each script reproduces:

* ``table2``   (table2_strategies.py)   — Table 2: wall clock per
  (input x app x strategy); also times the fully-jit SPMD round
  (``alb_spmd`` rows) and derives ALB-vs-TWC speedups.
* ``table2sim`` (table2_simulated.py)   — Table 2 with the paper's GPU
  cost model instead of wall clock (deterministic CI-friendly numbers).
* ``fig5``     (fig5_load_distribution.py) — Fig 1/5: per-tile edge
  loads round by round, TWC vs ALB, host and SPMD rounds.
* ``fig6``     (fig6_scaling.py)        — Fig 6/10: 1..8-device BSP
  scaling of the Gluon-analog runtime, TWC vs ALB, replicated vs
  mirror sync; also writes benchmarks/out/fig6_scaling.json with
  per-round comm volume (bytes_synced).
* ``fig8``     (fig8_cyclic_blocked.py) — Fig 8: cyclic vs blocked edge
  deal inside the LB executor (XLA and Pallas paths) + the Fig 4
  structural locality metric.
* ``fig9``     (fig9_partition.py)      — Fig 9: OEC/IEC/CVC partition
  policies (edge balance, mirrors, round counts).
* ``qps``      (fig_qps.py)             — batched multi-source query
  throughput: queries/sec of bfs_batch/sssp_batch vs batch size on the
  power-law input (DESIGN.md section 7); ``--smoke`` variant gates CI.
* ``serve``    (fig_serve.py)           — continuous-batching service
  throughput/latency vs the restart-per-batch baseline: Zipf traffic
  with the LRU cache + single-flight coalescing, Poisson-arrival
  latency sweep, deterministic slot-packing comparison (DESIGN.md
  section 8); ``--smoke`` variant gates CI.
* ``direction`` (fig_direction.py)      — push vs pull vs adaptive
  traversal direction per round (DESIGN.md section 9): wall clock,
  round counts, adaptive's pull share; ``--smoke`` gates parity and
  the adaptive direction trace structurally (no timing gate).
* ``update``   (fig_update.py)          — streaming edge updates:
  incremental label repair vs full recompute, rounds and wall clock
  per update on insert-only and mixed traces (DESIGN.md section 10);
  ``--smoke`` gates incremental/full parity and that insert-only
  repair rounds never exceed full-recompute rounds (no timing gate).
* ``fused``    (fig_fused.py)           — device-resident planning
  (DESIGN.md section 11): host vs fused round loops per app x graph;
  ``--smoke`` gates fused/host label parity, ``host_transfers == 0``
  per fused traversal, and the on-device direction trace against the
  host threshold rule replayed over device-recorded counts (no
  timing gate).
* ``fleet``    (fig_fleet.py)           — multi-replica serving fleet
  (DESIGN.md section 13): rendezvous-affinity hit rate vs the pure-P2C
  ablation, bounded-load ceiling audit, hedging under forced
  stragglers, and bitwise routing-trace replay; all gates structural
  (no timing gate), enforced at every scale.
* ``roofline`` (roofline.py)            — kernel roofline estimates
  from dry-run artifacts (skipped when artifacts are absent).

``-h``/``--help`` prints this selector table; an unknown selector is
an error (exit 2), not a silent no-op.

All inputs are synthetic analogues of the paper's graph classes (see
benchmarks/common.py: rmat = power-law, road = grid, uniform = flat).
"""
from __future__ import annotations

import sys


SELECTORS = ("table2", "table2sim", "fig5", "fig6", "fig8", "fig9",
             "qps", "serve", "direction", "update", "fused", "fleet",
             "roofline")


def main() -> None:
    argv = sys.argv[1:]
    if "-h" in argv or "--help" in argv:
        print(__doc__)
        return
    unknown = [a for a in argv if a not in SELECTORS]
    if unknown:
        print(f"unknown selector(s): {', '.join(sorted(unknown))}\n"
              f"valid selectors: {', '.join(SELECTORS)} "
              f"(see --help)", file=sys.stderr)
        sys.exit(2)
    which = set(argv) or set(SELECTORS)
    print("name,us_per_call,derived")
    if "table2" in which:
        from . import table2_strategies
        table2_strategies.run()
    if "table2sim" in which:
        from . import table2_simulated
        table2_simulated.run()
    if "fig5" in which:
        from . import fig5_load_distribution
        fig5_load_distribution.run()
    if "fig6" in which:
        from . import fig6_scaling
        fig6_scaling.run()
    if "fig8" in which:
        from . import fig8_cyclic_blocked
        fig8_cyclic_blocked.run()
    if "fig9" in which:
        from . import fig9_partition
        fig9_partition.run()
    if "qps" in which:
        from . import fig_qps
        fig_qps.run()
    if "serve" in which:
        from . import fig_serve
        fig_serve.run()
    if "direction" in which:
        from . import fig_direction
        if fig_direction.run():
            # structural gate failures (parity / adaptive trace) must
            # fail the aggregate run too, not just the --smoke entry
            sys.exit(1)
    if "update" in which:
        from . import fig_update
        if fig_update.run():
            # parity between incremental repair and full recompute is
            # a correctness property — fail the aggregate run
            sys.exit(1)
    if "fused" in which:
        from . import fig_fused
        if fig_fused.run():
            # fused/host parity and the zero-sync property are
            # correctness properties — fail the aggregate run
            sys.exit(1)
    if "fleet" in which:
        from . import fig_fleet
        if fig_fleet.run():
            # routing replay, the bounded-load ceiling, and hedge
            # publish-once/parity are correctness properties — fail
            # the aggregate run
            sys.exit(1)
    if "roofline" in which:
        from . import roofline
        try:
            roofline.main()
        except Exception as e:       # artifacts may not exist yet
            print(f"roofline,0,skipped ({e})")


if __name__ == "__main__":
    main()
