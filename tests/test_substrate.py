"""Substrate tests: optimizer, schedules, data determinism, checkpoint
fault tolerance, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw_init, adamw_update, OptConfig,
                         wsd_schedule, cosine_schedule)
from repro.optim.grad_compress import quantize, dequantize
from repro.data import SyntheticDataset
from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step, AsyncCheckpointer)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    cfg = OptConfig(lr=0.3, weight_decay=0.0)
    for _ in range(400):
        grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp p^2
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 5e-2


def test_adamw_clips_global_norm():
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    cfg = OptConfig(lr=1e-3, clip_norm=1.0)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, gnorm = adamw_update(params, huge, state, cfg)
    assert float(gnorm) == pytest.approx(2e9, rel=1e-3)


def test_wsd_schedule_phases():
    lr = wsd_schedule(1.0, warmup=10, stable=80, decay=10)
    assert float(lr(0)) == 0.0
    assert float(lr(5)) == pytest.approx(0.5)
    assert float(lr(50)) == pytest.approx(1.0)
    assert float(lr(100)) < 1.0
    assert float(lr(10_000)) == pytest.approx(0.01, rel=1e-2)


def test_cosine_schedule_monotone_decay():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    vals = [float(lr(s)) for s in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_data_deterministic_and_step_dependent():
    d = SyntheticDataset(seed=7, global_batch=4, seq_len=16,
                         vocab_size=100)
    a, b = d.batch(3), d.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones(4, np.int32)}}
    save_checkpoint(str(tmp_path), 7, tree, extra={"k": 1})
    assert latest_step(str(tmp_path)) == 7
    restored, manifest = restore_checkpoint(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])
    assert manifest["extra"] == {"k": 1}


def test_checkpoint_incomplete_ignored(tmp_path):
    tree = {"a": np.zeros(2)}
    save_checkpoint(str(tmp_path), 3, tree)
    # simulate a crash mid-write: directory without manifest
    os.makedirs(tmp_path / "step_00000009")
    assert latest_step(str(tmp_path)) == 3


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.submit(s, {"x": np.full(3, s)})
    ck.close()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [3, 4]
    restored, _ = restore_checkpoint(str(tmp_path), 4,
                                     {"x": np.zeros(3)})
    np.testing.assert_array_equal(restored["x"], np.full(3, 4))


def test_quantize_dequantize_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32) * 10)
    q, s, meta = quantize(x)
    y = dequantize(q, s, meta)
    # error bounded by half a quantum per element
    quantum = np.repeat(np.asarray(s), 256)[:1000]
    assert np.all(np.abs(np.asarray(y - x)) <= quantum * 0.5 + 1e-6)


def test_train_driver_restart(tmp_path):
    """Fault tolerance end-to-end: kill after N steps, restart, states
    must line up (deterministic data + checkpoint restore)."""
    from repro.launch.train import main as train_main
    ckpt = str(tmp_path / "ck")
    args = ["--arch", "llama3-8b", "--smoke", "--batch", "2",
            "--seq", "16", "--ckpt-dir", ckpt, "--ckpt-every", "4",
            "--log-every", "100"]
    loss_full = train_main(args + ["--steps", "10"])
    # second run resumes from step 9's checkpoint and just re-verifies
    loss_resumed = train_main(args + ["--steps", "10"])
    assert latest_step(ckpt) == 9
    assert np.isfinite(loss_full)


def test_master_weights_training_matches_f32_closely():
    """H2 mixed precision: bf16 params + f32 masters should track the
    full-f32 run to bf16 tolerance over a few steps."""
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.train.steps import make_train_step, init_train_state

    cfg = get_smoke_config("llama3-8b")
    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "labels": jnp.ones((2, 16), jnp.int32),
    }
    losses = {}
    for master in [False, True]:
        params, opt = init_train_state(jax.random.PRNGKey(0), cfg,
                                       master_weights=master)
        step = jax.jit(make_train_step(
            cfg, OptConfig(lr=1e-3, master_weights=master)))
        for _ in range(5):
            params, opt, m = step(params, opt, batch)
        losses[master] = float(m["loss"])
    assert abs(losses[True] - losses[False]) < 0.05, losses
