"""The ``Finding`` record every lint rule emits.

A finding is one violation at one source line.  Findings render as
``file:line rule-id message`` (the format CI greps and editors jump
to) and carry a line-number-free :attr:`Finding.baseline_key` so the
committed baseline file survives unrelated edits that shift code
around.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source location."""

    path: str
    """File the finding is in (repo-relative, ``/``-separated)."""

    line: int
    """1-based line number of the violating expression."""

    rule: str
    """Id of the rule that fired (e.g. ``host-sync``)."""

    message: str
    """Human-readable description of the violation."""

    def format(self) -> str:
        """Render as ``file:line rule-id message`` (the CLI format)."""
        return f"{self.path}:{self.line} {self.rule} {self.message}"

    @property
    def baseline_key(self) -> tuple:
        """Line-number-free identity used by the baseline file.

        Keyed on (path, rule, message) so grandfathered findings stay
        matched when unrelated edits move them to a different line.
        """
        return (self.path, self.rule, self.message)
