"""ShapeDtypeStruct stand-ins for every model input (dry-run contract).

No device allocation: everything here is abstract.  Modality frontends
are stubs per the assignment — ``input_specs`` supplies precomputed
patch embeddings (vlm) / token frames (audio) directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T


def _tok_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.num_codebooks > 1:
        return (batch, seq, cfg.num_codebooks)
    return (batch, seq)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for the step function selected by shape.kind."""
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        text = s - cfg.prefix_len
        specs = {
            "tokens": jax.ShapeDtypeStruct(_tok_shape(cfg, gb, text), i32),
            "labels": jax.ShapeDtypeStruct(_tok_shape(cfg, gb, text), i32),
        }
        if cfg.prefix_len:
            specs["prefix_emb"] = jax.ShapeDtypeStruct(
                (gb, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        return {
            "tokens": jax.ShapeDtypeStruct(_tok_shape(cfg, gb, s), i32),
            "cache": T.init_cache(cfg, gb, s),
        }
    if shape.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct(_tok_shape(cfg, gb, 1), i32),
            "cache": T.init_cache(cfg, gb, s),
        }
    raise ValueError(shape.kind)
