"""Production mesh construction (function, not module constant — see
the dry-run contract: importing this module must not touch device
state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(multi_pod: bool):
    """Axes that carry batch parallelism (pod stays pure-DP so the only
    cross-pod traffic is the per-step gradient reduce)."""
    return ("pod", "data") if multi_pod else ("data",)


def make_host_mesh(num_devices: int | None = None):
    """Small CPU mesh for tests/examples: (1, N) data×model."""
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
