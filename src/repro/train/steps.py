"""train_step / serve_step factories.

These close over the ModelConfig and an activation shard_fn; the
launcher jits them with explicit in/out shardings (pjit).  The same
functions back the smoke tests (1 CPU device, shard_fn = identity) and
the 512-chip dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, OptConfig

_IDENT = lambda name, x: x


def cross_entropy(logits, labels):
    """logits: [B, S, V] (or [B, S, n, V]); labels int32.
    Reduction always in f32 (logits may arrive bf16 under H4)."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0].astype(jnp.float32)
    return jnp.mean(logz - gold)


def make_loss_fn(cfg, shard_fn=_IDENT, remat: bool = True,
                 unroll: bool = False):
    def loss_fn(params, batch):
        prefix = batch.get("prefix_emb")
        logits, aux = T.forward(params, cfg, batch["tokens"],
                                prefix_emb=prefix, shard_fn=shard_fn,
                                remat=remat, unroll=unroll)
        if cfg.prefix_len:
            logits = logits[:, cfg.prefix_len:]
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux, ce
    return loss_fn


def make_train_step(cfg, opt_cfg: OptConfig, shard_fn=_IDENT,
                    remat: bool = True, unroll: bool = False):
    loss_fn = make_loss_fn(cfg, shard_fn, remat, unroll)

    def train_step(params, opt_state, batch):
        (loss, ce), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                opt_cfg)
        metrics = {"loss": loss, "ce": ce, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, shard_fn=_IDENT, unroll: bool = False):
    def prefill_step(params, tokens, cache, prefix_emb=None):
        return T.prefill(params, cfg, tokens, cache,
                         prefix_emb=prefix_emb, shard_fn=shard_fn,
                         unroll=unroll)
    return prefill_step


def make_decode_step(cfg, shard_fn=_IDENT, unroll: bool = False):
    def decode_step(params, token, cache):
        return T.decode_step(params, cfg, token, cache, shard_fn=shard_fn,
                             unroll=unroll)
    return decode_step


def init_train_state(key, cfg, master_weights: bool = False):
    params = T.init(key, cfg)
    if master_weights:
        # H2 mixed precision: bf16 model params, f32 masters in opt
        params = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16) if p.ndim > 1 else p,
            params)
    opt_state = adamw_init(params, master_weights=master_weights)
    return params, opt_state
