"""Gluon-analog distributed BSP runtime over shard_map.

Execution model (paper Section 2.1 / 5, DESIGN.md section 4): each
device computes a round on its local partition with the full ALB
machinery, then participates in a global synchronization that
reconciles vertex labels with the operator's combiner (min for
bfs/sssp/cc, add for pr/kcore deltas).

Labels are replicated (every vertex mirrored everywhere, see
partition.py); sync is a single ``pmin``/``psum`` over the ``dev`` mesh
axis — one fused all-reduce per round, matching Gluon's bulk
synchronous reduce-broadcast pair.

The per-device round is the fully-jit ``relax_spmd`` variant, whose
``lax.cond`` inspector skips the LB executor's work on devices whose
local partition has no huge frontier vertex this round — the paper's
adaptivity, per device.  ``relax_spmd`` dispatches through the executor
registry (DESIGN.md section 3), so ``BalancerConfig.use_pallas=True``
runs the Pallas LB/TWC mapping kernels *inside* ``shard_map``, and
``collect_stats=True`` threads jit-safe per-device ``RoundStatsDev``
through the same ``shard_map`` boundary (stacked along the ``dev``
axis).
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .graph import Graph, INF
from .balancer import BalancerConfig, RoundStats, RoundStatsDev, relax_spmd
from .operators import Operator
from . import operators as ops


def device_mesh(num_devices: int | None = None):
    devs = jax.devices()
    if num_devices is not None:
        devs = devs[:num_devices]
    return jax.sharding.Mesh(np.asarray(devs), ("dev",))


def _sync(labels, combine: str):
    if combine == "min":
        return jax.lax.pmin(labels, "dev")
    return jax.lax.psum(labels, "dev")


def make_round_fn(mesh, cfg: BalancerConfig, op: Operator,
                  sync_delta: bool = False, collect_stats: bool = False):
    """Build the jitted one-BSP-round function.

    sync_delta: for ``add``-combine operators the per-device scatter
    accumulates into a zero-initialized delta that is psum'd, then added
    to the replicated base — avoids double counting the base.

    collect_stats: the round function additionally returns a
    ``RoundStatsDev`` whose leaves carry a leading ``dev`` axis — one
    instrumentation record per device per round (Fig 1/5 in SPMD mode).
    """
    def round_fn(stacked_g: Graph, values, labels, frontier):
        # shard_map hands each device a [1, ...] block: squeeze to local
        stacked_g = Graph(row_ptr=stacked_g.row_ptr[0],
                          col_idx=stacked_g.col_idx[0],
                          edge_w=stacked_g.edge_w[0])
        # per-device local compute
        if sync_delta:
            delta = jnp.zeros_like(labels)
            out = relax_spmd(stacked_g, values, delta, frontier, cfg, op,
                             collect_stats=collect_stats)
            delta, st = out if collect_stats else (out, None)
            delta = _sync(delta, "add")
            new = labels + delta
        else:
            out = relax_spmd(stacked_g, values, labels, frontier, cfg, op,
                             collect_stats=collect_stats)
            new, st = out if collect_stats else (out, None)
            new = _sync(new, op.combine)
        if collect_stats:
            # leading axis of size 1 -> stacked to [D, ...] by out_specs
            return new, jax.tree_util.tree_map(lambda x: x[None], st)
        return new

    gspec = Graph(row_ptr=P("dev"), col_idx=P("dev"), edge_w=P("dev"))
    out_specs = P()
    if collect_stats:
        out_specs = (P(), RoundStatsDev(*([P("dev")] * 6)))
    fn = shard_map(round_fn, mesh=mesh,
                   in_specs=(gspec, P(), P(), P()),
                   out_specs=out_specs,
                   check_rep=False)
    return jax.jit(fn)


def stats_per_device(st: RoundStatsDev) -> list[RoundStats]:
    """Split a dev-stacked RoundStatsDev into one host RoundStats per
    device."""
    ndev = st.frontier_size.shape[0]
    return [RoundStats.from_device(
        jax.tree_util.tree_map(lambda x: x[d], st)) for d in range(ndev)]


def run_distributed(stacked_g: Graph, mesh, op: Operator,
                    init_labels, init_frontier,
                    cfg: BalancerConfig = BalancerConfig(),
                    values_of=lambda l: l,
                    next_frontier=lambda old, new, f: new < old,
                    sync_delta: bool = False,
                    max_rounds: int = 10_000,
                    collect_stats: bool = False):
    """Generic distributed data-driven loop. Returns (labels, rounds,
    total_seconds) — or, with ``collect_stats=True``, (labels, rounds,
    total_seconds, stats) where ``stats[round][device]`` is a host
    :class:`RoundStats` — the compute/comm split feeds the Fig 7/11
    breakdown and the per-device load plots."""
    round_fn = make_round_fn(mesh, cfg, op, sync_delta=sync_delta,
                             collect_stats=collect_stats)
    labels, frontier = init_labels, init_frontier
    rounds = 0
    stats = [] if collect_stats else None
    t0 = time.perf_counter()
    while rounds < max_rounds and bool(jnp.any(frontier)):
        old = labels
        out = round_fn(stacked_g, values_of(labels), labels, frontier)
        if collect_stats:
            labels, st = out
            stats.append(stats_per_device(st))
        else:
            labels = out
        jax.block_until_ready(labels)
        frontier = next_frontier(old, labels, frontier)
        rounds += 1
    total = time.perf_counter() - t0
    if collect_stats:
        return labels, rounds, total, stats
    return labels, rounds, total


# ---- distributed application drivers --------------------------------------

def sssp_distributed(stacked_g: Graph, mesh, source: int,
                     cfg: BalancerConfig = BalancerConfig(),
                     max_rounds: int = 10_000,
                     collect_stats: bool = False):
    v = stacked_g.row_ptr.shape[-1] - 1
    dist = jnp.full((v,), INF, jnp.int32).at[source].set(0)
    frontier = jnp.zeros((v,), bool).at[source].set(True)
    return run_distributed(stacked_g, mesh, ops.SSSP_RELAX, dist, frontier,
                           cfg, max_rounds=max_rounds,
                           collect_stats=collect_stats)


def bfs_distributed(stacked_g: Graph, mesh, source: int,
                    cfg: BalancerConfig = BalancerConfig(),
                    max_rounds: int = 10_000,
                    collect_stats: bool = False):
    v = stacked_g.row_ptr.shape[-1] - 1
    lvl = jnp.full((v,), INF, jnp.int32).at[source].set(0)
    frontier = jnp.zeros((v,), bool).at[source].set(True)
    return run_distributed(stacked_g, mesh, ops.BFS_HOP, lvl, frontier,
                           cfg, max_rounds=max_rounds,
                           collect_stats=collect_stats)


def cc_distributed(stacked_g: Graph, mesh,
                   cfg: BalancerConfig = BalancerConfig(),
                   max_rounds: int = 10_000,
                   collect_stats: bool = False):
    v = stacked_g.row_ptr.shape[-1] - 1
    comp = jnp.arange(v, dtype=jnp.int32)
    frontier = jnp.ones((v,), bool)
    return run_distributed(stacked_g, mesh, ops.CC_MIN, comp, frontier,
                           cfg, max_rounds=max_rounds,
                           collect_stats=collect_stats)


def pagerank_distributed(stacked_rg: Graph, mesh, out_degrees,
                         damping: float = 0.85, tol: float = 1e-6,
                         cfg: BalancerConfig = BalancerConfig(),
                         max_rounds: int = 1000,
                         collect_stats: bool = False):
    """stacked_rg: partitioned *reverse* graph (pull traverses in-edges)."""
    v = stacked_rg.row_ptr.shape[-1] - 1
    outdeg = out_degrees.astype(jnp.float32)
    inv_out = jnp.where(outdeg > 0, 1.0 / jnp.maximum(outdeg, 1.0), 0.0)
    rank = jnp.full((v,), 1.0 / v, jnp.float32)
    frontier = jnp.ones((v,), bool)
    round_fn = make_round_fn(mesh, cfg, ops.PR_PULL, sync_delta=True,
                             collect_stats=collect_stats)
    rounds = 0
    stats = [] if collect_stats else None
    t0 = time.perf_counter()
    while rounds < max_rounds:
        contrib = rank * inv_out
        out = round_fn(stacked_rg, contrib, jnp.zeros((v,), jnp.float32),
                       frontier)
        if collect_stats:
            acc, st = out
            stats.append(stats_per_device(st))
        else:
            acc = out
        new_rank = (1.0 - damping) / v + damping * acc
        delta = float(jnp.max(jnp.abs(new_rank - rank)))
        rank = new_rank
        rounds += 1
        if delta < tol:
            break
    total = time.perf_counter() - t0
    if collect_stats:
        return rank, rounds, total, stats
    return rank, rounds, total
