"""End-to-end training driver.

Runs any --arch on the local device set (CPU/TPU), with:
* deterministic synthetic data (restart-replayable),
* step-granular async checkpointing + automatic restart from the newest
  complete checkpoint,
* WSD or cosine LR schedule,
* optional int8 gradient compression on the data-parallel reduce,
* straggler-tolerant accounting (per-step wall clock + slowest-step
  watermark logged; on real fleets the BSP round time is max-over-hosts
  — the ALB design note in DESIGN.md section 4).

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --smoke --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, ARCH_IDS
from repro.data import SyntheticDataset
from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint)
from repro.optim import OptConfig, wsd_schedule, cosine_schedule
from repro.train.steps import make_train_step, init_train_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", choices=["cosine", "wsd"],
                    default="cosine")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    sched = (wsd_schedule(args.lr, warmup=max(args.steps // 20, 1),
                          stable=args.steps * 7 // 10,
                          decay=max(args.steps // 5, 1))
             if args.schedule == "wsd"
             else cosine_schedule(args.lr, max(args.steps // 20, 1),
                                  args.steps))
    opt_cfg = OptConfig(lr=sched)

    params, opt_state = init_train_state(jax.random.PRNGKey(args.seed),
                                         cfg)
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        newest = latest_step(args.ckpt_dir)
        if newest is not None:
            tmpl = {"params": params, "opt": opt_state}
            restored, manifest = restore_checkpoint(args.ckpt_dir,
                                                    newest, tmpl)
            params, opt_state = restored["params"], restored["opt"]
            start_step = newest + 1
            print(f"[restore] resumed from step {newest}")

    data = SyntheticDataset(args.seed, args.batch, args.seq,
                            cfg.vocab_size, cfg.num_codebooks)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    times = []
    metrics = None
    for step in range(start_step, args.steps):
        batch = jax.tree.map(jnp.asarray, data.batch(step))
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{dt * 1000:.0f}ms", flush=True)
        if ckpt and args.ckpt_dir and step % args.ckpt_every == 0 \
                and step > start_step:
            ckpt.submit(step, {"params": params, "opt": opt_state},
                        extra={"arch": args.arch})
    if ckpt:
        if metrics is not None:
            ckpt.submit(args.steps - 1,
                        {"params": params, "opt": opt_state},
                        extra={"arch": args.arch})
        ckpt.close()
    if times:
        arr = np.asarray(times[1:]) if len(times) > 1 else np.asarray(times)
        print(f"[timing] median {np.median(arr)*1000:.0f}ms "
              f"p95 {np.percentile(arr, 95)*1000:.0f}ms "
              f"(straggler watermark)")
    if metrics is None:          # resumed past the end: nothing to do
        print("[restore] checkpoint already at final step")
        return float("nan")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
