"""Pallas TPU kernel for the vertex-binned (TWC-analog) path.

Each grid step processes a tile of ``tile_v`` frontier vertices from one
degree bin; the bin's uniform width ``W`` is the lane dimension, so the
inner trip count is identical across lanes (the TPU analogue of the
warp-uniform execution TWC buys on GPUs).  Emits (graph_e, anchor, val,
mask) tiles; gather/scatter is applied outside by XLA (see edge_lb.py
for the rationale).

``chunk`` — the pass index for unbounded bins (each pass covers edges
[chunk*W, (chunk+1)*W) of every vertex) — is a *runtime scalar operand*
fed through a (1, 1) block, not a compile-time constant: the fully-jit
SPMD round (balancer.relax_spmd) iterates chunks with a
``lax.while_loop`` whose trip count is data-dependent, so the kernel
must accept a traced chunk.  The host-driven round passes Python ints,
which trace to the same single compiled kernel.

Batched queries (DESIGN.md section 7): the (graph_e, anchor, mask)
tiles depend only on the union frontier's bin members, so
``ops.twc_bin_apply*`` launch this kernel ONCE per round for the whole
batch and re-gather per-query values/activity from the ``[B, V]``
arrays in the XLA epilogue (the ``val`` output carries a single
query's view and is ignored there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(chunk_ref, vidx_ref, deg_ref, row_ref, val_ref,
            ge_ref, anchor_ref, val_out_ref, msk_ref,
            *, width: int, sentinel: int):
    deg = deg_ref[0, :]                        # [tile_v]
    row = row_ref[0, :]
    vid = vidx_ref[0, :]
    val = val_ref[0, :]
    chunk = chunk_ref[0, 0]
    off = (chunk * width
           + jax.lax.broadcasted_iota(jnp.int32, (deg.shape[0], width), 1))
    emask = (off < deg[:, None]) & (vid[:, None] < sentinel)
    ge_ref[...] = jnp.where(emask, row[:, None] + off, 0)
    anchor_ref[...] = jnp.broadcast_to(vid[:, None], emask.shape)
    val_out_ref[...] = jnp.broadcast_to(val[:, None], emask.shape)
    msk_ref[...] = emask.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("width", "tile_v", "sentinel", "interpret"))
def twc_bin_map(vidx: jax.Array, deg: jax.Array, row_start: jax.Array,
                val: jax.Array, *, width: int,
                chunk: jax.Array | int = 0,
                tile_v: int = 8, sentinel: int = 1 << 30,
                interpret: bool = True):
    """Expand one degree bin into (graph_e, anchor, val, mask) tiles."""
    b = vidx.shape[0]
    bp = -(-b // tile_v) * tile_v
    pad = bp - b
    if pad:
        vidx = jnp.pad(vidx, (0, pad), constant_values=sentinel)
        deg = jnp.pad(deg, (0, pad))
        row_start = jnp.pad(row_start, (0, pad))
        val = jnp.pad(val, (0, pad))
    grid = bp // tile_v
    # lane dim must be 128-aligned for the MXU/VPU; widths are powers of
    # two >= 8 in our configs, pad up when narrow.
    wp = max(width, 128) if width % 128 else width
    kern = functools.partial(_kernel, width=wp, sentinel=sentinel)
    chunk = jnp.asarray(chunk, jnp.int32).reshape(1, 1)
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    vec = pl.BlockSpec((1, tile_v), lambda i: (0, i))
    out_shape = [
        jax.ShapeDtypeStruct((bp, wp), jnp.int32),
        jax.ShapeDtypeStruct((bp, wp), jnp.int32),
        jax.ShapeDtypeStruct((bp, wp), val.dtype),
        jax.ShapeDtypeStruct((bp, wp), jnp.int32),
    ]
    outs = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[scalar, vec, vec, vec, vec],
        out_specs=[pl.BlockSpec((tile_v, wp), lambda i: (i, 0))] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(chunk, vidx[None, :], deg[None, :], row_start[None, :], val[None, :])
    ge, anchor, v, msk = outs
    if wp != width:
        # only the first `width` lanes are real when width < 128
        ge, anchor, v, msk = (x[:, :width] for x in (ge, anchor, v, msk))
    return ge, anchor, v, msk.astype(bool)
