"""``host-sync``: blocking device->host transfers must be registered.

Applying ``int()``/``bool()``/``float()``/``.item()``/``np.asarray()``
to a jnp expression (or to a local that was assigned one), or calling
``jax.device_get``, blocks the dispatch pipeline — the exact
per-round round-trip PR 7's fused mode exists to eliminate.  Inside
``src/repro/core`` and ``src/repro/serve`` every such sync must be a
*registered* transfer: the enclosing statement (or an adjacent one in
the same block) calls ``_note_host_transfer(...)``, so the PR 7
instrumentation counter and this lint's allowlist are literally the
same lines.  Intentional one-time syncs (pre-loop seeding, amortized
setup) carry a ``# repro: allow[host-sync] -- why`` pragma instead.
"""
from __future__ import annotations

import ast
from typing import List, Set

from .. import astutil
from ..findings import Finding
from ..registry import Rule, register_rule

RULE_ID = "host-sync"

#: name of the instrumentation hook from PR 7 — a statement adjacent
#: to a call of this is a registered transfer site
NOTE_NAME = "_note_host_transfer"

_SYNC_BUILTINS = {"int", "bool", "float"}
_ASARRAY = {"np.asarray", "numpy.asarray", "np.array", "numpy.array"}


def _is_note_stmt(stmt: ast.stmt) -> bool:
    if not isinstance(stmt, ast.Expr):
        return False
    call = stmt.value
    return (isinstance(call, ast.Call)
            and (astutil.dotted(call.func) or "").split(".")[-1]
            == NOTE_NAME)


def _propagates_taint(value: ast.AST, tainted: Set[str]) -> bool:
    """Whether assigning ``value`` taints its targets: the expression
    syntactically builds a jnp value, or aliases/slices an
    already-tainted name.  A user *function call* over tainted names
    does NOT propagate — its result type is unknowable statically, and
    the repo's round primitives deliberately return host-side data
    (e.g. ``relax_round(..., return_active=True)``) whose transfer is
    already accounted inside the callee."""
    if astutil.contains_jnp(value):
        return True
    if isinstance(value, ast.Call):
        return False
    if any(isinstance(sub, ast.Call) and not astutil.contains_jnp(sub)
           for sub in ast.walk(value)):
        # e.g. `x = f(tainted) + 1`: be conservative only about the
        # non-call parts
        stripped = [sub for sub in ast.iter_child_nodes(value)
                    if not isinstance(sub, ast.Call)]
        return any(astutil.references_names(sub, tainted)
                   for sub in stripped)
    return astutil.references_names(value, tainted)


def _walk_scope(scope: ast.AST):
    """Walk ``scope`` without descending into nested function/class
    defs — those are their own taint scopes (a nested traced body
    reusing a name must not taint the enclosing driver's)."""
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _jnp_tainted_names(scope: ast.AST) -> Set[str]:
    """Locals assigned (directly or transitively) from jnp
    expressions within ``scope`` — flow-insensitive fixpoint."""
    tainted: Set[str] = set()
    assigns = []
    for node in _walk_scope(scope):
        if isinstance(node, ast.Assign):
            assigns.append((node.targets, node.value))
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)) \
                and node.value is not None:
            assigns.append(([node.target], node.value))
    for _ in range(4):  # bounded fixpoint for chained assignments
        changed = False
        for targets, value in assigns:
            if _propagates_taint(value, tainted):
                for t in targets:
                    for sub in ast.walk(t):
                        if isinstance(sub, ast.Name) \
                                and sub.id not in tainted:
                            tainted.add(sub.id)
                            changed = True
        if not changed:
            break
    return tainted


def _sync_calls(stmt_expr: ast.AST, tainted: Set[str]):
    """Yield ``(node, what)`` for blocking syncs in an expression."""
    for node in ast.walk(stmt_expr):
        if not isinstance(node, ast.Call):
            continue
        fd = astutil.dotted(node.func)
        if fd == "jax.device_get":
            yield node, "jax.device_get(...)"
            continue
        if fd in _SYNC_BUILTINS and len(node.args) == 1:
            if _device_derived(node.args[0], tainted):
                yield node, f"{fd}() on a jnp expression"
            continue
        if fd in _ASARRAY and node.args:
            if _device_derived(node.args[0], tainted):
                yield node, f"{fd}() on a jnp expression"
            continue
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "item" and not node.args):
            if _device_derived(node.func.value, tainted):
                yield node, ".item() on a jnp expression"


def _device_derived(expr: ast.AST, tainted: Set[str]) -> bool:
    return (astutil.contains_jnp(expr)
            or astutil.references_names(expr, tainted))


def _child_blocks(stmt: ast.stmt):
    for field in ("body", "orelse", "finalbody"):
        block = getattr(stmt, field, None)
        if block:
            yield block
    for handler in getattr(stmt, "handlers", []) or []:
        yield handler.body


def _header_exprs(stmt: ast.stmt):
    """Expressions evaluated *by* a statement, excluding nested
    statement blocks (those get their own adjacency context)."""
    if isinstance(stmt, (ast.If, ast.While)):
        yield stmt.test
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        yield stmt.iter
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            yield item.context_expr
    elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
        return  # nested scope: walked separately
    else:
        yield stmt


def _walk_block(block, tainted, out, ctx):
    noted_idx = {i for i, s in enumerate(block) if _is_note_stmt(s)}
    for i, stmt in enumerate(block):
        noted = bool(noted_idx & {i - 1, i, i + 1})
        for expr in _header_exprs(stmt):
            for node, what in _sync_calls(expr, tainted):
                if noted:
                    continue
                out.append(ctx.finding(
                    node, RULE_ID,
                    f"blocking host sync: {what} — register it with "
                    f"{NOTE_NAME}() on an adjacent line, or pragma "
                    f"an intentional one-time transfer"))
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue  # nested scope: visited with its own taint set
        for sub in _child_blocks(stmt):
            _walk_block(sub, tainted, out, ctx)


def check(ctx) -> List[Finding]:
    """Run the host-sync pass over one file (core/ and serve/ only)."""
    if not (ctx.in_dir("repro", "core") or ctx.in_dir("repro", "serve")):
        return []
    out: List[Finding] = []
    # each function scope gets its own taint set; module scope too
    scopes = [n for n in ast.walk(ctx.tree)
              if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in scopes:
        tainted = _jnp_tainted_names(fn)
        _walk_block(fn.body, tainted, out, ctx)
    module_stmts = [s for s in ctx.tree.body
                    if not isinstance(s, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.ClassDef))]
    _walk_block(module_stmts, _jnp_tainted_names(ast.Module(
        body=module_stmts, type_ignores=[])), out, ctx)
    # class bodies hold methods (already covered) — skip their
    # remaining statements (field defaults are rule-exempt)
    return out


register_rule(Rule(
    id=RULE_ID,
    description="blocking device->host syncs in core/ and serve/ "
                "must sit next to _note_host_transfer() or carry a "
                "justified pragma",
    check=check,
))
