"""int8 gradient compression for the data-parallel reduce.

At 1000+ nodes the cross-pod gradient all-reduce rides the slow (DCN)
axis; block-scaled int8 quantization cuts those bytes 4x vs f32 (2x vs
bf16).  Scheme: per-block (last dim tiles of 256) absmax scale,
symmetric int8 quantize -> all-reduce in int32 (sums of int8 fit
easily) -> dequantize with the max scale.  The estimator is unbiased
per block up to rounding; 0.5-ulp stochastic rounding is left as a
config knob (deterministic rounding keeps tests exact).

Used inside shard_map over the mesh's data axes; see
tests/test_grad_compress.py for the numerical-error bound test.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    npad = -(-n // BLOCK) * BLOCK - n
    flat = x.reshape(-1)
    if npad:
        flat = jnp.pad(flat, (0, npad))
    return flat.reshape(-1, BLOCK), npad


def quantize(x):
    """x: any-shape f32/bf16 -> (int8 blocks, f32 scales, meta)."""
    blocks, npad = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], (x.shape, npad)


def dequantize(q, scale, meta):
    shape, npad = meta
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    if npad:
        flat = flat[:-npad] if npad else flat
    return flat.reshape(shape)


def compressed_psum(tree, axis_name):
    """All-reduce a gradient pytree over ``axis_name`` in int8.

    Each participant quantizes with its local scale, the int8 payloads
    are summed (psum over int32), scales are max-reduced, and the sum is
    dequantized with the max scale — a standard 1-bit-Adam-family
    approximation whose error is bounded by the scale quantum.
    """
    def one(g):
        q, scale, meta = quantize(g)
        smax = jax.lax.pmax(scale, axis_name)
        # requantize against the GLOBAL scale so summation is coherent
        blocks, npad = _pad_to_block(g.astype(jnp.float32))
        qg = jnp.clip(jnp.round(blocks / smax[:, None]), -127,
                      127).astype(jnp.int32)
        total = jax.lax.psum(qg, axis_name)
        out = total.astype(jnp.float32) * smax[:, None]
        flat = out.reshape(-1)
        if npad:
            flat = flat[:-npad]
        return flat.reshape(g.shape).astype(g.dtype)

    return jax.tree.map(one, tree)
