"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step, host) so that:
* restart-from-checkpoint replays the exact stream (fault tolerance),
* elastic re-sharding keeps the global batch content identical no
  matter how many hosts consume it (the key is global, slicing local).

Token streams are Zipf-distributed so embedding-gather traffic has a
realistic skew (and the MoE router sees non-uniform load — the ALB
dispatch's reason to exist).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_batch(seed: int, step: int, global_batch: int, seq_len: int,
                    vocab_size: int, num_codebooks: int = 1,
                    zipf_a: float = 1.2):
    """Host-side numpy generation (cheap, deterministic)."""
    rng = np.random.default_rng(np.uint64(seed) * 1_000_003
                                + np.uint64(step))
    shape = ((global_batch, seq_len) if num_codebooks == 1
             else (global_batch, seq_len, num_codebooks))
    z = rng.zipf(zipf_a, size=shape)
    tokens = np.minimum(z - 1, vocab_size - 1).astype(np.int32)
    return {"tokens": tokens[:, :-1] if num_codebooks == 1
            else tokens[:, :-1, :],
            "labels": tokens[:, 1:] if num_codebooks == 1
            else tokens[:, 1:, :]}


@dataclasses.dataclass
class SyntheticDataset:
    seed: int
    global_batch: int
    seq_len: int
    vocab_size: int
    num_codebooks: int = 1

    def batch(self, step: int):
        # +1 so tokens/labels both have seq_len after the shift
        return synthetic_batch(self.seed, step, self.global_batch,
                               self.seq_len + 1, self.vocab_size,
                               self.num_codebooks)
