"""Operator algebra for vertex programs.

A vertex program round applies an *operator* along edges of active
vertices (Section 2.1 of the paper).  We factor an operator into:

* ``direction``: ``push`` (value flows src -> dst, scatter at dst) or
  ``pull`` (value gathered from the neighbour, scatter at the anchor),
* ``msg``: candidate from the propagated vertex value + edge weight,
* ``combine``: how candidates merge at the target label (``min``/``add``).

Operators are module-level singletons so jit caches key on identity.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True, eq=False)
class Operator:
    name: str
    direction: str                    # 'push' | 'pull'
    combine: str                      # 'min'  | 'add'
    msg: Callable                     # (value, weight) -> candidate
    uses_weight: bool = True
    #: wire narrowings this operator's combine tolerates EXACTLY
    #: (DESIGN.md section 14), narrowest-preferred-last: dtype names
    #: the ``quantize`` wire codec may ship, first entry the default.
    #: Empty (the default) means "never narrow" — a ``wire="quantize"``
    #: config raises at config time.  The static ``dtype-narrowing``
    #: lint pass (repro.analysis) parses these declarations by AST —
    #: keep each a literal tuple of string constants.
    wire_narrow: tuple = ()


# Scatter combines that are commutative AND associative on the value
# domains the apps use, so `.at[idx].<combine>` with duplicate target
# indices is order-free and therefore deterministic: min/max always,
# add because every add-combine app scatters integers (kcore degree
# decrements) or is gated to a fixed reduction order elsewhere.  The
# static scatter-determinism pass (repro.analysis) parses this
# assignment by AST — keep it a literal frozenset of string constants.
COMMUTATIVE_COMBINES = frozenset({"min", "max", "add"})


# sssp relaxation: dist[dst] = min(dist[dst], dist[src] + w)
SSSP_RELAX = Operator("sssp_relax", "push", "min",
                      lambda v, w: v + w)

# bfs: level[dst] = min(level[dst], level[src] + 1).  Hop counts are
# bounded by the round budget, so uint16 (diameter < 65535) is always
# safe in practice and int8 (hops < 127) is safe for bounded-depth
# traversals — the narrow word's max value is the "unreached" sentinel
# (DESIGN.md section 14).
BFS_HOP = Operator("bfs_hop", "push", "min",
                   lambda v, w: v + 1, uses_weight=False,
                   wire_narrow=("uint16", "int8"))

# connected components (label propagation on symmetrized graph):
# comp[dst] = min(comp[dst], comp[src])
CC_MIN = Operator("cc_min", "push", "min",
                  lambda v, w: v, uses_weight=False)

# kcore: when a vertex dies, its (symmetrized) neighbours lose a degree.
# The uint16 wire word is exact for BOTH ring directions within the
# declared bound of max degree < 2^15: reduce-ring payloads are degree
# decrements (two's-complement wrap, sign-extended on decode — exact
# while |delta| < 2^15), and broadcast-ring payloads are the remaining
# degrees themselves (non-negative, zero-extended on decode — exact
# while label < 2^16).  Graphs with max degree >= 2^15 must not pair
# kcore with wire="quantize" (DESIGN.md section 14).
KCORE_DEC = Operator("kcore_dec", "push", "add",
                     lambda v, w: jnp.full_like(v, -1), uses_weight=False,
                     wire_narrow=("uint16",))

# pagerank (pull): acc[v] += contrib[u] for in-neighbours u; the per-
# vertex contribution rank[u]/outdeg[u] is precomputed as the value.
PR_PULL = Operator("pr_pull", "pull", "add",
                   lambda v, w: v, uses_weight=False)


# direction-optimized rounds (DESIGN.md section 9) flip a push operator
# to its pull twin: same msg/combine, but the value is gathered at the
# in-neighbour and combined at the anchor vertex over the reverse CSR.
# Memoized per operator: jit caches key on operator *identity*
# (eq=False), so every pull round of an app must see the SAME twin.
_PULL_TWINS: dict = {}


def as_pull(op: Operator) -> Operator:
    """The pull twin of a push min-combine operator (memoized).

    Only ``min``-combine push operators have an exact pull form here: a
    pull round enumerates every in-edge and neutralizes sources outside
    the frontier with the combiner identity, which is lossless for
    ``min`` but would reorder floating-point ``add`` reductions.
    """
    if op.direction != "push" or op.combine != "min":
        raise ValueError(
            f"direction-optimized rounds need a push min-combine "
            f"operator; got {op.name} (direction={op.direction!r}, "
            f"combine={op.combine!r})")
    if op not in _PULL_TWINS:
        _PULL_TWINS[op] = Operator(op.name + "@pull", "pull",
                                   op.combine, op.msg, op.uses_weight,
                                   op.wire_narrow)
    return _PULL_TWINS[op]
