"""Worklist (frontier) utilities.

The paper's D-IrGL baseline uses *implicit dense worklists* (a boolean
flag per vertex, Section 6.1); the GPU kernels are launched per round
with runtime-sized geometry.  We mirror both:

* dense frontier: ``bool[V]`` mask,
* compacted frontier: ``int32[F]`` vertex indices, padded with ``V``
  (an out-of-range sentinel, dropped by ``mode='drop'`` scatters), where
  ``F`` is a *bucketed* capacity so the per-round jitted functions are
  reused across rounds (the CPU/GPU analogue of launching a kernel with
  runtime grid size).

The batched query engine (DESIGN.md section 7) adds a third shape: a
*batch* of dense frontiers ``bool[B, V]``, one row per independent
query over the shared CSR.  The balancer round inspects the **union**
frontier (``union_frontier``) — binning, the huge-bin inspector, and
the LB prefix-sum deal run once for all B queries — while per-query
activity is recovered by gathering the ``[B, V]`` mask at each
enumerated edge's source vertex.

The serving engine (DESIGN.md section 8) treats each batch row as a
*slot* with a lifecycle: a row whose frontier empties has *retired*
(``rows_active``) and can be *refilled* mid-loop with a fresh source
(``refill_rows``) or restored from a preemption snapshot
(``load_rows``) — all at fixed ``[B, V]`` shapes, so the round loop
never recompiles across admissions.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def next_bucket(n: int, minimum: int = 64) -> int:
    """Smallest power of two >= max(n, minimum). Bounds re-jit count."""
    n = max(int(n), minimum)
    return 1 << (n - 1).bit_length()


@partial(jax.jit, static_argnames=("size",))
def compact(mask: jax.Array, size: int) -> jax.Array:
    """Indices of set bits, padded with len(mask) (sentinel)."""
    return jnp.nonzero(mask, size=size, fill_value=mask.shape[0])[0]


@jax.jit
def count(mask: jax.Array) -> jax.Array:
    return jnp.sum(mask.astype(jnp.int32))


@jax.jit
def dirty_mask(old: jax.Array, new: jax.Array) -> jax.Array:
    """Per-label "touched this round" bitvector (Gluon's dirty set):
    the master/mirror sync only exchanges vertices set here (DESIGN.md
    section 6).  Elementwise, so a batched ``[B, V]`` label pair yields
    a per-query dirty mask."""
    return new != old


@jax.jit
def dirty_vertices(old: jax.Array, new: jax.Array) -> jax.Array:
    """Per-**vertex** dirty mask: a vertex is dirty when its label
    changed in *any* query of the batch — the granularity the mirror
    sync ships at, since each dirty vertex carries its whole ``[B]``
    label vector (DESIGN.md section 7)."""
    d = new != old
    return d if d.ndim == 1 else jnp.any(d, axis=0)


@jax.jit
def union_frontier(frontier: jax.Array) -> jax.Array:
    """Dense union of a batch of frontiers: ``[B, V] -> [V]`` (identity
    on an un-batched ``[V]`` mask).  The balancer round plans bins and
    the LB deal over this union so one launch serves every query."""
    return frontier if frontier.ndim == 1 else jnp.any(frontier, axis=0)


@partial(jax.jit, static_argnames=("num_vertices",))
def seed_from_edges(src: jax.Array, dst: jax.Array, mask: jax.Array,
                    num_vertices: int) -> jax.Array:
    """Dense ``bool[V]`` frontier seeded from the endpoints of changed
    edges — the worklist an incremental label repair starts from
    (DESIGN.md section 10).  ``src``/``dst``/``mask`` are the
    fixed-capacity ``[K]`` arrays of an update delta (``mask`` False =
    padding slot); both endpoints of every live entry are set, so the
    repair round re-relaxes every edge whose shape or weight changed.
    Fixed ``K`` means one jit trace serves every batch of a stream."""
    off = jnp.zeros((num_vertices,), dtype=bool)
    ssafe = jnp.where(mask, src, num_vertices)     # sentinel: dropped
    dsafe = jnp.where(mask, dst, num_vertices)
    return off.at[ssafe].set(True, mode="drop") \
              .at[dsafe].set(True, mode="drop")


def full_frontier(num_vertices: int) -> jax.Array:
    return jnp.ones((num_vertices,), dtype=bool)


def single_source(num_vertices: int, src: int) -> jax.Array:
    return jnp.zeros((num_vertices,), dtype=bool).at[src].set(True)


def coerce_sources(sources) -> jax.Array:
    """Host-provided source vertices as a validated int32 ``[B]``
    vector — the ONE entry point through which batch source lists
    reach the device.  Centralizing the coercion keeps every batch
    init agreeing on dtype (int32 indexes the one-hot scatters) and
    shape (a scalar or nested list here would silently broadcast into
    the wrong frontier), and gives the host-sync lint a single
    annotated host->device crossing instead of per-caller copies."""
    srcs = jnp.asarray(sources, jnp.int32)
    if srcs.ndim != 1:
        raise ValueError(
            f"sources must be a flat [B] vector of vertex ids; got "
            f"shape {tuple(srcs.shape)}")
    return srcs


def single_sources(num_vertices: int, sources) -> jax.Array:
    """Batched one-hot frontiers ``bool[B, V]``: row b activates only
    ``sources[b]`` — the initial worklists of a multi-source batch."""
    srcs = coerce_sources(sources)
    b = srcs.shape[0]
    return jnp.zeros((b, num_vertices), dtype=bool) \
        .at[jnp.arange(b), srcs].set(True)


@jax.jit
def rows_active(frontier: jax.Array) -> jax.Array:
    """Per-slot liveness ``bool[B]`` of a batched frontier: row b is
    active while any of its vertices is on the worklist.  A row that
    goes inactive has *retired* — its query converged and its slot can
    be refilled (DESIGN.md section 8)."""
    return jnp.any(frontier, axis=-1)


@jax.jit
def refill_rows(labels: jax.Array, frontier: jax.Array,
                slots: jax.Array, sources: jax.Array, fill) -> tuple:
    """Admit fresh single-source queries into batch slots, in place of
    whatever the rows held before (DESIGN.md section 8).

    ``slots``/``sources`` are int32 ``[K]`` (pad unused entries with
    ``slots[k] = B`` — the out-of-range sentinel is dropped by the
    ``mode="drop"`` scatter, so one fixed ``K`` serves any number of
    admissions without re-jitting).  Each named slot's labels row is
    reset to ``fill`` with 0 at its own source and its frontier row to
    the one-hot source — exactly :func:`multi_source_state` for that
    row, so a refilled slot evolves bitwise like a standalone run.
    """
    v = labels.shape[-1]
    k = slots.shape[0]
    ssafe = jnp.clip(sources, 0, v - 1)
    lrows = jnp.full((k, v), fill, labels.dtype) \
        .at[jnp.arange(k), ssafe].set(0)
    frows = jnp.zeros((k, v), dtype=bool) \
        .at[jnp.arange(k), ssafe].set(True)
    return (labels.at[slots].set(lrows, mode="drop"),
            frontier.at[slots].set(frows, mode="drop"))


@jax.jit
def load_rows(labels: jax.Array, frontier: jax.Array, slots: jax.Array,
              label_rows: jax.Array, frontier_rows: jax.Array) -> tuple:
    """Restore snapshot rows into batch slots: the resume half of the
    serving engine's preempt/resume pair (DESIGN.md section 8).
    ``slots`` is int32 ``[K]`` (sentinel ``B`` entries dropped) and
    ``label_rows``/``frontier_rows`` are the ``[K, V]`` per-slot states
    captured when the queries were preempted; restoring them is exact,
    so a resumed query's labels evolve bitwise as if never paused."""
    return (labels.at[slots].set(label_rows, mode="drop"),
            frontier.at[slots].set(frontier_rows, mode="drop"))


def multi_source_state(num_vertices: int, sources, fill,
                       dtype=jnp.int32):
    """Initial ``[B, V]`` state of a multi-source batch: labels filled
    with ``fill`` except 0 at each query's own source, plus the one-hot
    frontiers.  The single entry-point init shared by the single-device
    and distributed batch drivers (so their label dtype/sentinel can
    never diverge)."""
    srcs = coerce_sources(sources)
    b = srcs.shape[0]
    labels = jnp.full((b, num_vertices), fill, dtype=dtype) \
        .at[jnp.arange(b), srcs].set(0)
    return labels, single_sources(num_vertices, srcs)
