"""Adaptive Load Balancer (ALB) — the paper's core contribution, on TPU.

Four strategies (Section 3 + 4 of the paper):

* ``vertex``  — vertex-based distribution: every active vertex processed
  as one unit of work regardless of degree (Section 3.1 strawman).
* ``twc``     — Thread-Warp-CTA analog: active vertices binned by degree
  (small/medium/large); each bin processed with a uniform inner width.
  The large bin is UNBOUNDED, which is exactly the thread-block
  imbalance the paper fixes (Section 3.2).
* ``edge_lb`` — non-adaptive edge-balanced distribution (Gunrock-LB
  analog): ALL frontier edges are renumbered by prefix sum and dealt
  evenly (Section 3.3).
* ``alb``     — the paper's scheme: TWC bins for degree < THRESHOLD plus
  a ``huge`` bin; an inspector checks whether the huge bin is nonempty
  and only then runs the edge-balanced (LB) executor (Section 4).

TPU mapping (DESIGN.md section 2): GPU thread blocks -> Pallas grid
tiles; warps/threads -> VPU lanes; atomicMin -> XLA scatter-min;
the inspector -> a vector reduction + host/`lax.cond` dispatch; cyclic
vs blocked edge deal -> lane-major contiguous vs strided edge-id order.

Two execution modes:

* host-driven (``relax``): per-round host decisions + bucketed jit
  functions — mirrors per-round GPU kernel launches; used for the
  single-device wall-clock benchmarks.
* fully-jit (``relax_spmd``): static capacities + ``lax.cond`` — used
  inside ``shard_map`` for the distributed (Gluon-analog) runtime.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .frontier import next_bucket, compact
from .operators import Operator


@dataclasses.dataclass(frozen=True)
class BalancerConfig:
    strategy: str = "alb"            # vertex | twc | edge_lb | alb
    threshold: int = 1024            # paper: #threads launched
    small_width: int = 8             # thread-level bin
    medium_width: int = 128          # warp-level bin
    large_width: int = 1024          # CTA chunk width (per pass)
    distribution: str = "cyclic"     # cyclic | blocked (Section 4.1)
    num_tiles: int = 64              # "thread blocks" for stats/kernels
    use_pallas: bool = False         # route hot loops through Pallas
    lb_tile_edges: int = 2048        # edge tile per grid step (LB kernel)

    def __post_init__(self):
        assert self.strategy in ("vertex", "twc", "edge_lb", "alb")
        assert self.distribution in ("cyclic", "blocked")


class RoundStats(NamedTuple):
    """Instrumentation for Fig 1/5-style plots."""
    frontier_size: int
    edges_twc: int          # edges processed by the vertex-binned path
    edges_lb: int           # edges processed by the edge-balanced path
    lb_invoked: bool        # did the inspector fire the LB executor?
    tile_loads_twc: np.ndarray   # per-tile edge counts, TWC path
    tile_loads_lb: np.ndarray    # per-tile edge counts, LB path


# ---------------------------------------------------------------------------
# jitted building blocks (cached per static shape bucket)
# ---------------------------------------------------------------------------

@jax.jit
def _frontier_meta(g: Graph, frontier_idx: jax.Array):
    """degree / row start / validity for a compacted frontier."""
    v = g.row_ptr.shape[0] - 1
    valid = frontier_idx < v
    safe = jnp.where(valid, frontier_idx, 0)
    deg = jnp.where(valid, g.row_ptr[safe + 1] - g.row_ptr[safe], 0)
    row_start = jnp.where(valid, g.row_ptr[safe], 0)
    return deg, row_start, valid


def _apply(labels, target, cand, mask, combine):
    """scatter-combine candidates into labels (atomicMin/atomicAdd analog)."""
    v = labels.shape[0]
    tgt = jnp.where(mask, target, v)          # out of range => dropped
    if combine == "min":
        return labels.at[tgt].min(cand.astype(labels.dtype), mode="drop")
    if combine == "add":
        return labels.at[tgt].add(
            jnp.where(mask, cand, 0).astype(labels.dtype), mode="drop")
    raise ValueError(combine)


@partial(jax.jit, static_argnames=("width", "op", "chunk"))
def _bin_pass(g: Graph, values, labels, vidx, deg, row_start,
              width: int, op: Operator, chunk: int = 0):
    """Process one degree bin: each vertex in ``vidx`` contributes its
    edges [chunk*width, chunk*width + width) — the uniform-trip-count
    vertex-tiled path (TWC small/medium/large analog).

    Shapes: vidx/deg/row_start: [B];  produces a [B, width] edge tile.
    """
    base = chunk * width
    off = base + jnp.arange(width, dtype=jnp.int32)[None, :]      # [1,W]
    emask = off < deg[:, None]                                     # [B,W]
    graph_e = jnp.where(emask, row_start[:, None] + off, 0)
    dst = g.col_idx[graph_e]
    w = g.edge_w[graph_e]
    if op.direction == "push":
        vsafe = jnp.where(vidx < values.shape[0], vidx, 0)
        val = values[vsafe][:, None]                               # [B,1]
        cand = op.msg(jnp.broadcast_to(val, emask.shape), w)
        new = _apply(labels, dst, cand, emask, op.combine)
    else:  # pull: value gathered at the neighbour, scattered at anchor
        val = values[dst]
        cand = op.msg(val, w)
        anchor = jnp.broadcast_to(vidx[:, None], emask.shape)
        new = _apply(labels, anchor, cand, emask, op.combine)
    return new


@partial(jax.jit, static_argnames=("ecap", "op", "distribution", "num_tiles"))
def _lb_pass(g: Graph, values, labels, hidx, hdeg, hrow_start,
             total_edges, ecap: int, op: Operator,
             distribution: str, num_tiles: int):
    """The LB executor (Figure 3, SSSP_LB): edge-balanced renumbering.

    Edges of the huge vertices get global ids 0..total_edges-1 via an
    exclusive prefix sum over their degrees; each edge id is mapped back
    to (src, graph edge) by binary search (searchsorted) in that prefix
    array — the paper's CSR-preserving trick.  ``distribution`` controls
    the edge-id -> lane order (cyclic = consecutive lanes process
    consecutive edges; blocked = strided) — Section 4.1 / Figure 4.
    """
    start_e = jnp.cumsum(hdeg) - hdeg                  # exclusive prefix
    # enumerate a multiple of num_tiles so the blocked permutation below
    # is a bijection of [0, n_enum) and cannot miss edges
    w_per = -(-ecap // num_tiles)
    n_enum = w_per * num_tiles
    eid = jnp.arange(n_enum, dtype=jnp.int32)
    if distribution == "blocked":
        # thread T_i gets the contiguous chunk [i*w_per, (i+1)*w_per):
        # lane-major order becomes strided by w_per (Figure 4 right).
        eid = (eid % num_tiles) * w_per + eid // num_tiles
    emask = eid < total_edges
    eid_c = jnp.where(emask, eid, 0)
    j = jnp.searchsorted(start_e, eid_c, side="right") - 1   # src slot
    j = jnp.clip(j, 0, hidx.shape[0] - 1)
    graph_e = hrow_start[j] + (eid_c - start_e[j])
    graph_e = jnp.where(emask, graph_e, 0)
    src = hidx[j]
    dst = g.col_idx[graph_e]
    w = g.edge_w[graph_e]
    if op.direction == "push":
        vsafe = jnp.where(src < values.shape[0], src, 0)
        cand = op.msg(values[vsafe], w)
        return _apply(labels, dst, cand, emask, op.combine)
    else:
        cand = op.msg(values[dst], w)
        return _apply(labels, src, cand, emask, op.combine)


@partial(jax.jit, static_argnames=("num_tiles",))
def _tile_loads(deg, valid, num_tiles: int):
    """Per-tile edge counts when frontier vertices are dealt to tiles in
    compacted order (Fig 1/5 instrumentation)."""
    f = deg.shape[0]
    tile = (jnp.arange(f, dtype=jnp.int32) * num_tiles) // max(f, 1)
    return jnp.zeros((num_tiles,), jnp.int32).at[tile].add(
        jnp.where(valid, deg, 0).astype(jnp.int32))


# ---------------------------------------------------------------------------
# host-driven round (per-round "kernel launches", bucketed jit)
# ---------------------------------------------------------------------------

def relax(g: Graph, values: jax.Array, labels: jax.Array,
          frontier: jax.Array, cfg: BalancerConfig, op: Operator,
          collect_stats: bool = False):
    """One round: apply ``op`` along all edges of active vertices.

    Returns (new_labels, RoundStats|None).  ``values`` is the per-vertex
    quantity being propagated (may alias ``labels``); ``labels`` is the
    array updated by scatter-combine.
    """
    nf = int(jnp.sum(frontier))
    if nf == 0:
        return labels, None
    fcap = next_bucket(nf)
    fidx = compact(frontier, fcap)
    deg, row_start, valid = _frontier_meta(g, fidx)

    use_pallas = cfg.use_pallas
    stats = dict(frontier_size=nf, edges_twc=0, edges_lb=0,
                 lb_invoked=False,
                 tile_loads_twc=np.zeros(cfg.num_tiles, np.int64),
                 tile_loads_lb=np.zeros(cfg.num_tiles, np.int64))

    def run_bin(labels, mask, width, unbounded=False):
        n = int(jnp.sum(mask))
        if n == 0:
            return labels
        cap = next_bucket(n)
        sel = compact(mask, cap)                       # slots into fidx
        sel_safe = jnp.where(sel < fcap, sel, 0)
        bvidx = jnp.where(sel < fcap, fidx[sel_safe], labels.shape[0])
        bdeg = jnp.where(sel < fcap, deg[sel_safe], 0)
        brow = jnp.where(sel < fcap, row_start[sel_safe], 0)
        max_d = int(jnp.max(bdeg))
        passes = 1 if not unbounded else -(-max_d // width)
        for c in range(passes):
            labels = _bin_run(g, values, labels, bvidx, bdeg, brow,
                              width, op, c, use_pallas)
        if collect_stats:
            stats["edges_twc"] += int(jnp.sum(bdeg))
            stats["tile_loads_twc"] += np.asarray(
                _tile_loads(bdeg, bvidx < labels.shape[0], cfg.num_tiles))
        return labels

    s = cfg.strategy
    if s == "vertex":
        # one unit of work per vertex, inner width = whole adjacency
        labels = run_bin(labels, valid, cfg.large_width, unbounded=True)
    elif s == "twc":
        labels = run_bin(labels, valid & (deg <= cfg.small_width),
                         cfg.small_width)
        labels = run_bin(labels, valid & (deg > cfg.small_width)
                         & (deg <= cfg.medium_width), cfg.medium_width)
        # CTA bin: UNBOUNDED degree — the paper's imbalance culprit
        labels = run_bin(labels, valid & (deg > cfg.medium_width),
                         cfg.large_width, unbounded=True)
    elif s in ("edge_lb", "alb"):
        if s == "edge_lb":
            huge = valid & (deg > 0)              # everything, non-adaptive
        else:
            # bins must be DISJOINT with the huge bin or add-combine
            # operators double-count (min-combine would mask the bug)
            huge = valid & (deg >= cfg.threshold)  # the new `huge` bin
            below = valid & (deg < cfg.threshold)
            labels = run_bin(labels, below & (deg <= cfg.small_width)
                             & (deg > 0), cfg.small_width)
            labels = run_bin(labels, below & (deg > cfg.small_width)
                             & (deg <= cfg.medium_width), cfg.medium_width)
            labels = run_bin(labels, below & (deg > cfg.medium_width),
                             cfg.large_width, unbounded=True)
        # ---- inspector (Section 4.1): is the huge bin non-empty? ----
        n_huge = int(jnp.sum(huge))
        if n_huge > 0:
            hcap = next_bucket(n_huge)
            sel = compact(huge, hcap)
            sel_safe = jnp.where(sel < fcap, sel, 0)
            hvidx = jnp.where(sel < fcap, fidx[sel_safe], labels.shape[0])
            hdeg = jnp.where(sel < fcap, deg[sel_safe], 0)
            hrow = jnp.where(sel < fcap, row_start[sel_safe], 0)
            total = int(jnp.sum(hdeg))
            if total > 0:
                ecap = next_bucket(total, minimum=cfg.lb_tile_edges)
                labels = _lb_run(g, values, labels, hvidx, hdeg, hrow,
                                 jnp.int32(total), ecap, op,
                                 cfg.distribution, cfg.num_tiles,
                                 use_pallas, cfg.lb_tile_edges)
                if collect_stats:
                    stats["edges_lb"] = total
                    stats["lb_invoked"] = True
                    per = np.full(cfg.num_tiles,
                                  total // cfg.num_tiles, np.int64)
                    per[: total % cfg.num_tiles] += 1
                    stats["tile_loads_lb"] = per
    return labels, (RoundStats(**stats) if collect_stats else None)


def _bin_run(g, values, labels, bvidx, bdeg, brow, width, op, chunk,
             use_pallas):
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.twc_bin_apply(g, values, labels, bvidx, bdeg, brow,
                                  width, op, chunk)
    return _bin_pass(g, values, labels, bvidx, bdeg, brow, width, op, chunk)


def _lb_run(g, values, labels, hvidx, hdeg, hrow, total, ecap, op,
            distribution, num_tiles, use_pallas, tile_edges):
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.edge_lb_apply(g, values, labels, hvidx, hdeg, hrow,
                                  total, ecap, op, distribution, tile_edges)
    return _lb_pass(g, values, labels, hvidx, hdeg, hrow, total, ecap, op,
                    distribution, num_tiles)


# ---------------------------------------------------------------------------
# fully-jit SPMD round (for shard_map / distributed execution)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "op"))
def relax_spmd(g: Graph, values: jax.Array, labels: jax.Array,
               frontier: jax.Array, cfg: BalancerConfig, op: Operator):
    """Static-shape ALB round: capacities fixed at V/E, LB path guarded
    by ``lax.cond`` so balanced rounds skip its cost at runtime (the
    SPMD realization of the inspector-executor split)."""
    v = labels.shape[0]
    fidx = compact(frontier, v)
    deg, row_start, valid = _frontier_meta(g, fidx)
    huge = valid & (deg >= cfg.threshold)

    # TWC bins at full capacity
    def bin_apply(labels, mask, width, passes):
        bvidx = jnp.where(mask, fidx, v)
        bdeg = jnp.where(mask, deg, 0)
        brow = jnp.where(mask, row_start, 0)
        for c in range(passes):
            labels = _bin_pass(g, values, labels, bvidx, bdeg, brow,
                               width, op, c)
        return labels

    below = valid & (deg < cfg.threshold)        # disjoint from huge bin
    labels = bin_apply(labels, below & (deg <= cfg.small_width) & (deg > 0),
                       cfg.small_width, 1)
    labels = bin_apply(labels, below & (deg > cfg.small_width)
                       & (deg <= cfg.medium_width), cfg.medium_width, 1)
    # large bin is bounded by threshold in ALB
    n_large_passes = -(-cfg.threshold // cfg.large_width)
    labels = bin_apply(labels, below & (deg > cfg.medium_width),
                       cfg.large_width, n_large_passes)

    n_huge = jnp.sum(huge.astype(jnp.int32))
    ecap = g.col_idx.shape[0]

    def lb_branch(labels):
        hvidx = jnp.where(huge, fidx, v)
        hdeg = jnp.where(huge, deg, 0)
        hrow = jnp.where(huge, row_start, 0)
        total = jnp.sum(hdeg)
        return _lb_pass(g, values, labels, hvidx, hdeg, hrow, total,
                        ecap, op, cfg.distribution, cfg.num_tiles)

    labels = jax.lax.cond(n_huge > 0, lb_branch, lambda l: l, labels)
    return labels
