"""Wire codec layer acceptance (DESIGN.md section 14).

Unit tests cover the codec registry, config-time validation, the
encode/decode round-trips and the byte accountants on plain arrays —
all single-device, tier-1.

The multi-device tests are the refactor's acceptance gates: for every
app x sync x mode cell the labels after decode must be BITWISE equal
to the ``identity`` codec run; ``delta`` and ``bitmap`` must put
strictly fewer bytes on the wire than the logical ``bytes_synced`` on
every non-final round of the gate workloads (structural, no
wall-clock); and ``quantize`` on an operator that declares no safe
narrowing must raise at config time, before any round is traced.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import graph as G
from repro.core import gluon
from repro.core import operators as ops
from repro.core import wire
from repro.core.balancer import BalancerConfig
from repro.core.partition import partition

NDEV = 4
multidevice = pytest.mark.skipif(
    len(jax.devices()) < NDEV,
    reason=f"needs {NDEV} devices (CI sets "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={NDEV})")


# ---------------- registry + config-time validation ------------------------

def test_registry_names_resolve():
    for name in ("identity", "delta", "bitmap"):
        assert wire.get_codec(name).name == name
    q = wire.get_codec("quantize", ops.BFS_HOP)
    assert q.name == "quantize"
    assert q.narrow == ops.BFS_HOP.wire_narrow[0] == "uint16"
    q8 = wire.get_codec("quantize:int8", ops.BFS_HOP)
    assert q8.narrow == "int8"


def test_unknown_wire_spec_raises():
    with pytest.raises(ValueError, match="unknown wire codec"):
        wire.get_codec("zstd")
    with pytest.raises(ValueError, match="not a supported"):
        wire.get_codec("quantize:int64")


def test_balancer_config_validates_wire():
    for name in ("identity", "delta", "bitmap", "quantize",
                 "quantize:uint16"):
        assert BalancerConfig(wire=name).wire == name
    with pytest.raises(ValueError, match="unknown wire codec"):
        BalancerConfig(wire="bogus")


def test_quantize_requires_declared_narrowing():
    # sssp/cc declare none: their min combine must carry full labels
    for op in (ops.SSSP_RELAX, ops.CC_MIN):
        with pytest.raises(ValueError, match="declares none"):
            wire.get_codec("quantize", op)
    # a narrowing outside the declared set is rejected even though the
    # dtype itself is supported
    with pytest.raises(ValueError, match="not.*among them"):
        wire.get_codec("quantize:int8", ops.KCORE_DEC)
    # float payloads never narrow exactly
    with pytest.raises(ValueError, match="integer payloads"):
        wire.WireCodec("quantize", narrow="uint16").validate(
            ops.BFS_HOP, jnp.float32)
    # pagerank: no declaration AND float — raises on the first check
    with pytest.raises(ValueError):
        wire.get_codec("quantize", ops.PR_PULL, jnp.float32)


# ---------------- encode/decode round-trips --------------------------------

def test_delta_int_round_trip_exact():
    rng = np.random.default_rng(0)
    payload = jnp.asarray(rng.integers(0, 1 << 30, (3, 64)), jnp.int32)
    prev = jnp.asarray(rng.integers(0, 1 << 30, (3, 64)), jnp.int32)
    # include the combiner neutral (2^31 - 1): the subtraction wraps,
    # the addition wraps back — two's complement keeps it exact
    payload = payload.at[0, 0].set(np.int32((1 << 31) - 1))
    enc = wire.DELTA.encode(payload, prev, ops.SSSP_RELAX)
    dec = wire.DELTA.decode(enc, prev, ops.SSSP_RELAX, jnp.int32)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(payload))


def test_delta_float_ships_raw():
    payload = jnp.asarray([[0.1, 0.7]], jnp.float32)
    prev = jnp.asarray([[0.05, 0.7]], jnp.float32)
    enc = wire.DELTA.encode(payload, prev, ops.PR_PULL)
    np.testing.assert_array_equal(np.asarray(enc), np.asarray(payload))


def test_quantize_min_round_trip_with_sentinel():
    codec = wire.get_codec("quantize", ops.BFS_HOP)
    hops = jnp.asarray([[0, 7, 65534, int(G.INF), (1 << 31) - 1]],
                       jnp.int32)
    prev = jnp.zeros_like(hops)
    enc = codec.encode(hops, prev, ops.BFS_HOP)
    assert enc.dtype == jnp.uint16
    dec = codec.decode(enc, prev, ops.BFS_HOP, jnp.int32)
    # reachable hops exact; INF and the combiner neutral both map
    # through the saturating sentinel to INF — a no-op under min
    np.testing.assert_array_equal(
        np.asarray(dec[0]), [0, 7, 65534, int(G.INF), int(G.INF)])


def test_quantize_add_round_trip_sign_extends():
    codec = wire.get_codec("quantize", ops.KCORE_DEC)
    deltas = jnp.asarray([[0, -1, -37, -32768 + 1, 255]], jnp.int32)
    prev = jnp.zeros_like(deltas)
    enc = codec.encode(deltas, prev, ops.KCORE_DEC)
    assert enc.dtype == jnp.uint16
    dec = codec.decode(enc, prev, ops.KCORE_DEC, jnp.int32)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(deltas))


def test_quantize_add_broadcast_labels_zero_extend():
    """The broadcast ring ships full labels (kcore's remaining
    degrees), which are non-negative: ``signed=False`` zero-extends
    the uint16 word, so degrees in [2^15, 2^16) round-trip exactly
    instead of decoding negative through sign-extension."""
    codec = wire.get_codec("quantize", ops.KCORE_DEC)
    labels = jnp.asarray([[0, 7, 32768, 40000, 65535]], jnp.int32)
    prev = jnp.zeros_like(labels)
    enc = codec.encode(labels, prev, ops.KCORE_DEC)
    assert enc.dtype == jnp.uint16
    dec = codec.decode(enc, prev, ops.KCORE_DEC, jnp.int32,
                       signed=False)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(labels))
    # the reduce-ring (signed) widening would corrupt these labels —
    # the asymmetry is the point of the direction-aware decode
    signed_dec = codec.decode(enc, prev, ops.KCORE_DEC, jnp.int32)
    assert int(signed_dec[0, 2]) < 0


def test_quantize_int8_round_trip():
    codec = wire.get_codec("quantize:int8", ops.BFS_HOP)
    hops = jnp.asarray([[0, 3, 126, int(G.INF)]], jnp.int32)
    dec = codec.decode(
        codec.encode(hops, jnp.zeros_like(hops), ops.BFS_HOP),
        jnp.zeros_like(hops), ops.BFS_HOP, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(dec[0]), [0, 3, 126, int(G.INF)])


# ---------------- byte accountants -----------------------------------------

def _slab(b=2, n=32, n_live=10, seed=1):
    rng = np.random.default_rng(seed)
    payload = jnp.asarray(rng.integers(0, 1000, (b, n)), jnp.int32)
    live = jnp.asarray(np.arange(n) < n_live)
    return payload, live


def test_step_logical_bytes_counts_index_word():
    _, live = _slab()
    got = int(wire.step_logical_bytes(live, 2, 4))
    assert got == 10 * (wire.INDEX_BYTES + 2 * 4)


def test_identity_wire_equals_logical():
    payload, live = _slab()
    got = int(wire.IDENTITY.step_wire_bytes(
        payload, payload, live, ops.SSSP_RELAX))
    assert got == int(wire.step_logical_bytes(live, 2, 4))


def test_quantize_wire_bytes_scale_by_narrow_itemsize():
    payload, live = _slab()
    codec = wire.get_codec("quantize", ops.BFS_HOP)   # uint16
    got = int(codec.step_wire_bytes(payload, payload, live, ops.BFS_HOP))
    assert got == 10 * (wire.INDEX_BYTES + 2 * 2)


def test_bitmap_wire_bytes_hybrid():
    payload, live = _slab(n=64, n_live=40)
    # dense: the 8-bytes bitmap (64 slots / 8) beats 40 index words
    got = int(wire.BITMAP.step_wire_bytes(
        payload, payload, live, ops.SSSP_RELAX))
    assert got == 8 + 40 * 2 * 4
    # sparse: the raw index list wins, bitmap degenerates to identity
    payload, live = _slab(n=64, n_live=1)
    got = int(wire.BITMAP.step_wire_bytes(
        payload, payload, live, ops.SSSP_RELAX))
    assert got == 1 * wire.INDEX_BYTES + 1 * 2 * 4
    # empty step ships nothing at all
    payload, live = _slab(n=64, n_live=0)
    assert int(wire.BITMAP.step_wire_bytes(
        payload, payload, live, ops.SSSP_RELAX)) == 0


def test_delta_wire_bytes_suppress_unchanged():
    payload, live = _slab(b=4, n=32, n_live=16)
    # nothing changed: only indices + the 2-bit code stream remain
    got = int(wire.DELTA.step_wire_bytes(
        payload, payload, live, ops.SSSP_RELAX))
    assert got == 16 * wire.INDEX_BYTES + 16 * 1
    assert got < int(wire.step_logical_bytes(live, 4, 4))
    # everything changed, values clustered within a 1-byte spread of
    # the per-query frame-of-reference base: 1-byte entries + one base
    # word per query still undercut the 4-byte payload words
    rng = np.random.default_rng(7)
    payload = jnp.asarray(rng.integers(1000, 1200, (4, 32)), jnp.int32)
    prev = payload - 3
    got = int(wire.DELTA.step_wire_bytes(
        payload, prev, live, ops.SSSP_RELAX))
    assert got == (16 * wire.INDEX_BYTES + 16 * 1   # codes
                   + 4 * 4                          # per-query bases
                   + 16 * 4 * 1)                    # 1-byte offsets
    assert got < int(wire.step_logical_bytes(live, 4, 4))


def test_delta_wire_bytes_float_mask_path():
    rng = np.random.default_rng(2)
    payload = jnp.asarray(rng.random((1, 16)), jnp.float32)
    live = jnp.asarray(np.arange(16) < 8)
    prev = payload.at[0, :4].add(1.0)    # 4 changed among the 8 live
    got = int(wire.DELTA.step_wire_bytes(
        payload, prev, live, ops.PR_PULL))
    assert got == 8 * wire.INDEX_BYTES + 8 * 1 + 4 * 4


def test_allreduce_wire_bytes():
    new = jnp.asarray(np.arange(64).reshape(1, 64), jnp.int32)
    prev = new.at[0, :16].add(1)
    assert int(wire.IDENTITY.allreduce_wire_bytes(new, prev)) == 64 * 4
    assert int(wire.BITMAP.allreduce_wire_bytes(new, prev)) == 64 * 4
    assert int(wire.DELTA.allreduce_wire_bytes(new, prev)) == 8 + 16 * 4
    q = wire.get_codec("quantize", ops.BFS_HOP)
    assert int(q.allreduce_wire_bytes(new, prev)) == 64 * 2


def test_shared_block_helpers_round_trip():
    x = jnp.asarray(np.random.default_rng(3).random(300), jnp.float32)
    blocks, npad = wire.pad_to_block(x)
    assert blocks.shape == (2, wire.BLOCK)
    assert npad == 2 * wire.BLOCK - 300
    scale = wire.block_absmax_scale(blocks)
    assert scale.shape == (2, 1)
    assert float(jnp.max(jnp.abs(blocks / scale))) <= 127.0 + 1e-6


def test_grad_compress_uses_shared_helpers():
    from repro.optim import grad_compress as gc
    assert gc.pad_to_block is wire.pad_to_block
    assert gc.BLOCK == wire.BLOCK
    q, scale, meta = gc.quantize(
        jnp.asarray(np.random.default_rng(4).random(513), jnp.float32))
    out = gc.dequantize(q, scale, meta)
    assert out.shape == (513,)


# ---------------- acceptance gates (multi-device) --------------------------

CFG = BalancerConfig(strategy="alb", threshold=64)


@pytest.fixture(scope="module")
def rmat_graph():
    return G.rmat(9, 8, seed=5)


@multidevice
@pytest.mark.parametrize("codec", ["delta", "bitmap", "quantize"])
@pytest.mark.parametrize("sync", ["replicated", "mirror"])
@pytest.mark.parametrize("mode", ["host", "fused"])
def test_bfs_codec_parity(rmat_graph, codec, sync, mode):
    """Labels after decode are BITWISE equal to the identity run for
    every sync substrate and execution mode."""
    g = rmat_graph
    src = G.highest_out_degree_vertex(g)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, "oec")
    m = meta if sync == "mirror" else None
    ref, _, _ = gluon.bfs_distributed(sg, mesh, src, CFG, sync=sync,
                                      meta=m, mode=mode)
    got, _, _ = gluon.bfs_distributed(
        sg, mesh, src, BalancerConfig(strategy="alb", threshold=64,
                                      wire=codec),
        sync=sync, meta=m, mode=mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
@pytest.mark.parametrize("app", ["cc", "kcore"])
@pytest.mark.parametrize("codec", ["delta", "bitmap"])
def test_symmetric_apps_codec_parity(rmat_graph, app, codec):
    g = G.symmetrized(rmat_graph)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, "oec")
    driver = (gluon.cc_distributed if app == "cc"
              else lambda *a, **k: gluon.kcore_distributed(
                  a[0], a[1], 8, *a[2:], **k))
    ref, _, _ = driver(sg, mesh, CFG, sync="mirror", meta=meta)
    cfg = BalancerConfig(strategy="alb", threshold=64, wire=codec)
    got, _, _ = driver(sg, mesh, cfg, sync="mirror", meta=meta)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
def test_kcore_quantize_codec_parity(rmat_graph):
    """kcore + quantize exercises both add-combine widenings through
    the real rings: sign-extended decrements on the reduce ring,
    zero-extended remaining degrees on the broadcast ring."""
    g = G.symmetrized(rmat_graph)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, "oec")
    ref, _, _ = gluon.kcore_distributed(sg, mesh, 8, CFG,
                                        sync="mirror", meta=meta)
    cfg = BalancerConfig(strategy="alb", threshold=64, wire="quantize")
    got, _, _ = gluon.kcore_distributed(sg, mesh, 8, cfg,
                                        sync="mirror", meta=meta)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
@pytest.mark.parametrize("codec", ["delta", "bitmap"])
def test_pagerank_codec_parity(rmat_graph, codec):
    g = rmat_graph
    mesh = gluon.device_mesh(NDEV)
    srg, rmeta = partition(G.reverse_graph(g), NDEV, "oec")
    ref, _, _ = gluon.pagerank_distributed(
        srg, mesh, g.out_degrees(), max_rounds=10, tol=0.0,
        sync="mirror", meta=rmeta)
    got, _, _ = gluon.pagerank_distributed(
        srg, mesh, g.out_degrees(), max_rounds=10, tol=0.0,
        cfg=BalancerConfig(wire=codec), sync="mirror", meta=rmeta)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
@pytest.mark.parametrize("codec", ["delta", "bitmap"])
def test_compression_strict_on_nonfinal_rounds(codec):
    """The structural gate: on the batched-BFS gate workload (dense
    boundary traffic on every pre-convergence round, B=8 payload
    vectors) delta and bitmap put STRICTLY fewer bytes on the wire
    than the logical volume on every non-final round.  (bitmap's
    hybrid index side degenerates to the identity layout on sparse
    steps — the gate workload is chosen so no non-final round is that
    sparse.)"""
    g = G.rmat(10, 8, seed=3)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, "oec")
    srcs = np.asarray([0, 7, 23, 99, 200, 311, 450, 512])
    cfg = BalancerConfig(strategy="alb", threshold=64, wire=codec)
    _, rounds, _, stats = gluon.bfs_batch_distributed(
        sg, mesh, srcs, cfg, collect_stats=True, sync="mirror",
        meta=meta)
    per_round = [(sum(st.bytes_synced for st in pr),
                  sum(st.bytes_wire for st in pr)) for pr in stats]
    assert rounds >= 3          # a real traversal, not a degenerate one
    for logical, wired in per_round[:-1]:
        assert 0 < wired < logical, per_round


@multidevice
def test_quantize_strict_on_nonfinal_rounds(rmat_graph):
    """uint16 hop payloads halve the payload side on every round that
    ships anything (quantize compresses unconditionally — no density
    requirement)."""
    g = rmat_graph
    src = G.highest_out_degree_vertex(g)
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, "oec")
    cfg = BalancerConfig(strategy="alb", threshold=64, wire="quantize")
    _, _, _, stats = gluon.bfs_distributed(
        sg, mesh, src, cfg, collect_stats=True, sync="mirror", meta=meta)
    for pr in stats:
        for st in pr:
            assert st.bytes_wire == st.mirrors_synced * (4 + 2)
            if st.mirrors_synced:
                assert st.bytes_wire < st.bytes_synced


@multidevice
def test_quantize_raises_at_config_time_distributed(rmat_graph):
    """The driver refuses quantize on an operator with no declared
    narrowing BEFORE tracing or dispatching any round."""
    g = rmat_graph
    mesh = gluon.device_mesh(NDEV)
    sg, meta = partition(g, NDEV, "oec")
    cfg = BalancerConfig(wire="quantize")
    with pytest.raises(ValueError, match="declares none"):
        gluon.sssp_distributed(sg, mesh, 0, cfg, sync="mirror",
                               meta=meta)
    with pytest.raises(ValueError):
        gluon.pagerank_distributed(sg, mesh, g.out_degrees(), cfg=cfg)
