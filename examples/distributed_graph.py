"""Multi-device graph analytics: CuSP-analog partitioning + Gluon-analog
BSP sync, the paper's D-IrGL(ALB) system (Sections 5/6.2).

Re-execs itself with 4 forced host devices (CPU stand-ins for GPUs).

  PYTHONPATH=src python examples/distributed_graph.py
"""
import os
import subprocess
import sys

if os.environ.get("_REPRO_INNER") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["_REPRO_INNER"] = "1"
    sys.exit(subprocess.run([sys.executable, __file__], env=env).returncode)

import numpy as np
import jax

from repro.core import graph as G
from repro.core.partition import partition, partition_stats
from repro.core import gluon
from repro.core.balancer import BalancerConfig
from repro.core.apps import sssp

g = G.rmat(12, 16, seed=0)
src = G.highest_out_degree_vertex(g)
print(f"devices: {len(jax.devices())}; graph |V|={g.num_vertices} "
      f"|E|={g.num_edges}")

ref = sssp(g, src, BalancerConfig(strategy="alb", threshold=1024))

mesh = gluon.device_mesh(4)
for policy in ["oec", "iec", "cvc"]:
    sg, meta = partition(g, 4, policy)
    st = partition_stats(sg, meta)
    for strat in ["twc", "alb"]:
        cfg = BalancerConfig(strategy=strat, threshold=1024)
        for sync in ["replicated", "mirror"]:
            labels, rounds, secs, stats = gluon.sssp_distributed(
                sg, mesh, src, cfg, collect_stats=True,
                sync=sync, meta=meta)
            ok = np.array_equal(np.asarray(labels), np.asarray(ref.labels))
            comm = sum(st.bytes_synced
                       for per_round in stats for st in per_round)
            print(f"{policy}/{strat:4s}/{sync:10s}: {secs * 1e3:7.1f} ms  "
                  f"rounds={rounds} edge-imbalance={st['imbalance']:.2f} "
                  f"replication={st['replication_factor']:.2f} "
                  f"synced={comm / 1024:.1f}KiB correct={ok}")
