"""Continuous-batching query service (repro.serve, DESIGN.md
section 8).

The invariants under test:

* **Mid-loop refill parity** — every query served through the slot
  engine (including queries admitted into a slot another query just
  vacated, and queries preempted/resumed) returns labels bitwise equal
  to its standalone ``bfs``/``sssp`` run.
* **Fairness** — with a round budget, a giant-diameter query cannot
  starve the queue: short queries complete in O(budget) rounds, the
  giant still finishes correctly.
* **Cache** — repeat queries hit the LRU cache; re-registering a graph
  id invalidates its entries.
* **Determinism** — identical submissions produce identical admission
  sequences (and identical results), run to run.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph as G
from repro.core.apps import bfs, sssp
from repro.core.apps.drivers import QUERY_APPS, step_batch
from repro.core.balancer import BalancerConfig, relax
from repro.core.frontier import (multi_source_state, rows_active,
                                 refill_rows, load_rows)
from repro.serve import (QueryService, ResultCache, Scheduler, SlotView,
                         QUEUED, RUNNING, DONE)

CFG = BalancerConfig(strategy="alb", threshold=32)
STANDALONE = {"bfs": bfs, "sssp": sssp}


@pytest.fixture(scope="module")
def rmat_g():
    return G.rmat(8, 8, seed=3)


@pytest.fixture(scope="module")
def path_star_g():
    """One graph, two workload shapes: an 80-hop path (the
    giant-diameter query) and a star (1–2 round queries)."""
    n_path, hub, leaves = 80, 80, range(82, 90)
    src = list(range(n_path)) + [hub] * len(list(leaves))
    dst = list(range(1, n_path + 1)) + list(leaves)
    return G.from_edge_list(np.asarray(src), np.asarray(dst), 90)


def _sources(g, n, seed=0):
    deg = np.asarray(g.out_degrees())
    cand = np.flatnonzero(deg > 0)
    rng = np.random.default_rng(seed)
    picks = rng.choice(cand, size=n, replace=False)
    return [int(v) for v in picks]


# ---------------------------------------------------------------------------
# lifecycle primitives
# ---------------------------------------------------------------------------

def test_rows_active_and_refill(rmat_g):
    g = rmat_g
    labels, frontier = multi_source_state(g.num_vertices, [1, 2, 3],
                                          G.INF)
    act = np.asarray(rows_active(frontier))
    assert act.tolist() == [True, True, True]
    # refill slot 1 with source 5, sentinel-pad the rest
    slots = jnp.asarray([1, 3, 3], jnp.int32)      # 3 == B: dropped
    srcs = jnp.asarray([5, 0, 0], jnp.int32)
    labels2, frontier2 = refill_rows(labels, frontier, slots, srcs,
                                     G.INF)
    ref_l, ref_f = multi_source_state(g.num_vertices, [1, 5, 3], G.INF)
    assert np.array_equal(np.asarray(labels2), np.asarray(ref_l))
    assert np.array_equal(np.asarray(frontier2), np.asarray(ref_f))
    # sentinel rows untouched
    assert np.array_equal(np.asarray(labels2[0]), np.asarray(labels[0]))


def test_load_rows_restores_snapshot(rmat_g):
    g = rmat_g
    labels, frontier = multi_source_state(g.num_vertices, [1, 2], G.INF)
    snap_l = np.asarray(labels[0])
    snap_f = np.asarray(frontier[0])
    labels2, frontier2 = refill_rows(
        labels, frontier, jnp.asarray([0, 2], jnp.int32),
        jnp.asarray([7, 0], jnp.int32), G.INF)
    b = labels.shape[0]
    labels3, frontier3 = load_rows(
        labels2, frontier2, jnp.asarray([0, b], jnp.int32),
        jnp.asarray(np.stack([snap_l, snap_l])),
        jnp.asarray(np.stack([snap_f, snap_f])))
    assert np.array_equal(np.asarray(labels3), np.asarray(labels))
    assert np.array_equal(np.asarray(frontier3), np.asarray(frontier))


def test_relax_return_active(rmat_g):
    g = rmat_g
    op, fill = QUERY_APPS["bfs"]
    labels, frontier = multi_source_state(g.num_vertices, [1, 2], fill)
    frontier = frontier.at[1].set(False)           # row 1 retired
    out, st, active = relax(g, labels, labels, frontier, CFG, op,
                            return_active=True)
    assert active.tolist() == [True, False]
    # empty union: early return still reports per-row liveness
    empty = jnp.zeros_like(frontier)
    out, st, active = relax(g, labels, labels, empty, CFG, op,
                            return_active=True)
    assert active.tolist() == [False, False]


def test_step_batch_rejects_non_min_ops(rmat_g):
    from repro.core import operators as ops
    labels, frontier = multi_source_state(rmat_g.num_vertices, [1], G.INF)
    with pytest.raises(ValueError, match="min-combine"):
        step_batch(rmat_g, labels, frontier, CFG, ops.KCORE_DEC)


# ---------------------------------------------------------------------------
# mid-loop refill parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("app", ["bfs", "sssp"])
@pytest.mark.parametrize("strategy", ["alb", "twc"])
def test_served_queries_match_standalone(rmat_g, app, strategy):
    """More queries than slots => slots are refilled mid-loop as
    earlier queries retire; every result must still be bitwise equal to
    its standalone single-source run."""
    g = rmat_g
    cfg = BalancerConfig(strategy=strategy, threshold=32)
    svc = QueryService(num_slots=3, cfg=cfg)
    svc.register_graph("g", g)
    sources = _sources(g, 10, seed=1)
    qids = [svc.submit("g", app, s) for s in sources]
    svc.run()
    for qid, s in zip(qids, sources):
        q = svc.poll(qid)
        assert q.status == DONE and not q.from_cache
        ref = np.asarray(STANDALONE[app](g, s, cfg).labels)
        assert np.array_equal(q.result, ref), f"{app} from {s}"
    # refills actually happened: 10 queries through 3 slots
    assert len(svc.admission_log) == 10
    assert svc.stats.queries_served == 10


def test_served_queries_match_standalone_spmd(rmat_g):
    """Same parity through the fully-jit (relax_spmd) round mode."""
    g = rmat_g
    svc = QueryService(num_slots=2, cfg=CFG, mode="spmd")
    svc.register_graph("g", g)
    sources = _sources(g, 5, seed=2)
    qids = [svc.submit("g", "bfs", s) for s in sources]
    svc.run()
    for qid, s in zip(qids, sources):
        ref = np.asarray(bfs(g, s, CFG, mode="spmd").labels)
        assert np.array_equal(svc.poll(qid).result, ref)


def test_mixed_apps_one_service(rmat_g):
    """bfs and sssp queries on the same graph run in separate slot
    banks but one service; both keep parity."""
    g = rmat_g
    svc = QueryService(num_slots=2, cfg=CFG)
    svc.register_graph("g", g)
    sources = _sources(g, 4, seed=3)
    q_bfs = [svc.submit("g", "bfs", s) for s in sources]
    q_sssp = [svc.submit("g", "sssp", s) for s in sources]
    svc.run()
    for qid, s in zip(q_bfs, sources):
        assert np.array_equal(svc.poll(qid).result,
                              np.asarray(bfs(g, s, CFG).labels))
    for qid, s in zip(q_sssp, sources):
        assert np.array_equal(svc.poll(qid).result,
                              np.asarray(sssp(g, s, CFG).labels))


def test_zero_out_degree_source(rmat_g):
    """A source with no outgoing edges converges in one round with only
    itself labelled — same as standalone."""
    g = rmat_g
    deg = np.asarray(g.out_degrees())
    sinks = np.flatnonzero(deg == 0)
    if len(sinks) == 0:
        pytest.skip("input has no zero-out-degree vertex")
    s = int(sinks[0])
    svc = QueryService(num_slots=2, cfg=CFG)
    svc.register_graph("g", g)
    qid = svc.submit("g", "bfs", s)
    svc.run()
    assert np.array_equal(svc.poll(qid).result,
                          np.asarray(bfs(g, s, CFG).labels))


# ---------------------------------------------------------------------------
# fairness
# ---------------------------------------------------------------------------

def test_round_budget_prevents_starvation(path_star_g):
    """B=1, one 80-round query ahead of three 1–2 round queries: with a
    round budget the shorts finish in O(budget); without one they wait
    for the giant's whole eccentricity.  The preempted giant still
    matches its standalone run bitwise."""
    g = path_star_g
    short_srcs = [80, 82, 83]                      # hub + two leaves

    def serve(budget):
        svc = QueryService(num_slots=1, cfg=CFG, round_budget=budget)
        svc.register_graph("p", g)
        giant = svc.submit("p", "bfs", 0)
        shorts = [svc.submit("p", "bfs", s) for s in short_srcs]
        svc.run()
        return svc, giant, shorts

    svc, giant, shorts = serve(budget=None)
    starved = [svc.poll(q).rounds_in_system for q in shorts]
    assert min(starved) > 70                       # run-to-completion
    assert svc.stats.preemptions == 0

    svc, giant, shorts = serve(budget=5)
    fair = [svc.poll(q).rounds_in_system for q in shorts]
    assert max(fair) <= 15                         # O(budget), not O(D)
    gq = svc.poll(giant)
    assert gq.preemptions >= 1
    assert np.array_equal(gq.result, np.asarray(bfs(g, 0, CFG).labels))
    assert svc.stats.preemptions >= 1


def test_preempt_resume_parity_multislot(path_star_g):
    """Preemption under contention with B=2: every query (preempted or
    not) keeps standalone parity."""
    g = path_star_g
    svc = QueryService(num_slots=2, cfg=CFG, round_budget=4)
    svc.register_graph("p", g)
    sources = [0, 10, 80, 82, 83, 84, 20]          # two deep, rest short
    qids = [svc.submit("p", "bfs", s) for s in sources]
    svc.run()
    assert svc.stats.preemptions >= 1
    for qid, s in zip(qids, sources):
        assert np.array_equal(svc.poll(qid).result,
                              np.asarray(bfs(g, s, CFG).labels))


def test_scheduler_plan_is_pure_and_bounded():
    """Unit: preempt only what idle slots can't absorb, fill free
    slots FIFO in ascending order."""
    sch = Scheduler(round_budget=3)
    slots = [SlotView(0, qid=7, slot_rounds=5),
             SlotView(1, qid=8, slot_rounds=9),
             SlotView(2, qid=None, slot_rounds=0)]
    # one pending query and one idle slot: no preemption needed
    d = sch.plan(slots, pending=1)
    assert d.preempt == () and d.admit == (2,)
    # two pending, one idle: preempt ONE over-budget slot (longest
    # residency first) and refill it plus the idle slot
    d = sch.plan(slots, pending=2)
    assert d.preempt == (1,)
    assert d.admit == (1, 2)
    # three pending, one idle: both over-budget slots yield
    d = sch.plan(slots, pending=3)
    assert d.preempt == (1, 0)                     # residency order
    assert d.admit == (0, 1, 2)
    d = sch.plan(slots, pending=0)
    assert d.preempt == () and d.admit == ()
    d = Scheduler(round_budget=None).plan(slots, pending=5)
    assert d.preempt == () and d.admit == (2,)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def test_cache_hit_and_invalidation_on_reregistration(rmat_g):
    g1 = rmat_g
    g2 = G.rmat(8, 8, seed=99)                     # different binding
    s = _sources(g1, 1, seed=4)[0]
    svc = QueryService(num_slots=2, cfg=CFG)
    svc.register_graph("g", g1)

    q1 = svc.submit("g", "bfs", s)
    svc.run()
    q2 = svc.submit("g", "bfs", s)                 # answered at submit
    r1, r2 = svc.poll(q1), svc.poll(q2)
    assert not r1.from_cache and r2.from_cache
    assert r2.status == DONE and r2.rounds_in_system == 0
    assert np.array_equal(r1.result, r2.result)
    assert svc.cache.hits == 1

    svc.register_graph("g", g2)                    # invalidates "g"
    q3 = svc.submit("g", "bfs", s)
    assert svc.poll(q3).status == QUEUED           # real work again
    svc.run()
    r3 = svc.poll(q3)
    assert not r3.from_cache
    assert np.array_equal(r3.result, np.asarray(bfs(g2, s, CFG).labels))


def test_single_flight_coalescing(rmat_g):
    """Identical submissions while the first is still in flight never
    occupy a slot: one device computation serves all of them."""
    g = rmat_g
    s = _sources(g, 1, seed=8)[0]
    svc = QueryService(num_slots=2, cfg=CFG)
    svc.register_graph("g", g)
    qids = [svc.submit("g", "bfs", s) for _ in range(4)]   # cold cache
    other = svc.submit("g", "bfs", _sources(g, 2, seed=9)[1])
    svc.run()
    ref = np.asarray(bfs(g, s, CFG).labels)
    primary, followers = svc.poll(qids[0]), [svc.poll(q) for q in qids[1:]]
    assert not primary.from_cache
    for f in followers:
        assert f.from_cache and f.status == DONE
        assert np.array_equal(f.result, ref)
    assert np.array_equal(primary.result, ref)
    # only the primary (and the unrelated query) were ever admitted
    admitted = {qid for _, qid, _ in svc.admission_log}
    assert admitted == {qids[0], other}
    assert svc.stats.cache_hits == 3 and svc.stats.cache_misses == 2


def test_reregistration_rejected_while_in_flight(path_star_g):
    svc = QueryService(num_slots=1, cfg=CFG)
    svc.register_graph("p", path_star_g)
    svc.submit("p", "bfs", 0)
    with pytest.raises(ValueError, match="in flight"):
        svc.register_graph("p", path_star_g)


def test_cache_keyed_by_strategy(rmat_g):
    """Different BalancerConfig => different cache key (no cross-hit),
    same bitwise labels either way."""
    s = _sources(rmat_g, 1, seed=5)[0]
    svc = QueryService(num_slots=2, cfg=CFG)
    svc.register_graph("g", rmat_g)
    q1 = svc.submit("g", "bfs", s)
    svc.run()
    other = BalancerConfig(strategy="twc")
    assert svc.cache.get("g", "bfs", s, other) is None
    assert svc.cache.get("g", "bfs", s, CFG) is not None
    # the wire codec is part of the frozen config and therefore of the
    # cache key: a config differing ONLY in wire must not cross-hit
    import dataclasses
    rewired = dataclasses.replace(CFG, wire="delta")
    assert rewired != CFG
    assert svc.cache.get("g", "bfs", s, rewired) is None


def test_result_cache_lru_eviction():
    c = ResultCache(capacity=2)
    c.put("g", "bfs", 0, "a", np.zeros(1))
    c.put("g", "bfs", 1, "a", np.ones(1))
    assert c.get("g", "bfs", 0, "a") is not None   # 0 now most recent
    c.put("g", "bfs", 2, "a", np.ones(1))          # evicts 1
    assert c.get("g", "bfs", 1, "a") is None
    assert c.get("g", "bfs", 0, "a") is not None
    assert len(c) == 2
    disabled = ResultCache(capacity=0)
    disabled.put("g", "bfs", 0, "a", np.zeros(1))
    assert disabled.get("g", "bfs", 0, "a") is None


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_deterministic_scheduler_order(path_star_g):
    """Identical submissions => identical admission traces and
    identical per-query results, run to run (including preemptions)."""
    def serve():
        svc = QueryService(num_slots=2, cfg=CFG, round_budget=4)
        svc.register_graph("p", path_star_g)
        qids = [svc.submit("p", "bfs", s)
                for s in [0, 80, 10, 82, 83, 20]]
        svc.run()
        return (svc.admission_log,
                [svc.poll(q).result for q in qids],
                [svc.poll(q).rounds_in_system for q in qids])

    log_a, res_a, lat_a = serve()
    log_b, res_b, lat_b = serve()
    assert log_a == log_b
    assert lat_a == lat_b
    for a, b in zip(res_a, res_b):
        assert np.array_equal(a, b)


def test_fifo_admission_order(rmat_g):
    """Without preemption, queries are admitted in submission (qid)
    order."""
    svc = QueryService(num_slots=2, cfg=CFG)
    svc.register_graph("g", rmat_g)
    qids = [svc.submit("g", "bfs", s) for s in _sources(rmat_g, 6, 6)]
    svc.run()
    admitted = [qid for _, qid, _ in svc.admission_log]
    assert admitted == sorted(admitted) == qids


# ---------------------------------------------------------------------------
# submit validation + stats
# ---------------------------------------------------------------------------

def test_submit_validation(rmat_g):
    svc = QueryService(num_slots=1, cfg=CFG)
    svc.register_graph("g", rmat_g)
    with pytest.raises(ValueError, match="unknown graph"):
        svc.submit("nope", "bfs", 0)
    with pytest.raises(ValueError, match="unknown app"):
        svc.submit("g", "pagerank", 0)
    with pytest.raises(ValueError, match="out of range"):
        svc.submit("g", "bfs", rmat_g.num_vertices)


def test_service_stats_accounting(rmat_g):
    svc = QueryService(num_slots=4, cfg=CFG)
    svc.register_graph("g", rmat_g)
    sources = _sources(rmat_g, 6, seed=7)
    for s in sources:
        svc.submit("g", "bfs", s)
    st = svc.run()
    svc.submit("g", "bfs", sources[0])             # one cache hit
    assert st.queries_served == 7
    assert st.cache_hits == 1 and st.cache_misses == 6
    assert 0 < st.occupancy <= 1
    assert st.latency_percentile(50) <= st.latency_percentile(95)
    s = st.summary()
    assert s["queries_served"] == 7
    assert s["cache_hit_rate"] == pytest.approx(1 / 7, abs=1e-4)


# ---------------------------------------------------------------------------
# published results are read-only shared state
# ---------------------------------------------------------------------------

def test_published_results_are_readonly(rmat_g):
    """The LRU entry, the primary's ``poll().result`` and every
    coalesced follower share ONE ndarray — mutating a polled result
    must raise, and a later re-poll / cache hit must be unchanged
    (before the fix, the write succeeded and silently corrupted every
    future hit)."""
    svc = QueryService(num_slots=2, cfg=CFG)
    svc.register_graph("g", rmat_g)
    src = _sources(rmat_g, 1, seed=11)[0]
    qid = svc.submit("g", "bfs", src)
    svc.run()
    res = svc.poll(qid).result
    expected = res.copy()
    with pytest.raises(ValueError):
        res[0] = -1
    # re-poll: unchanged object, unchanged contents
    np.testing.assert_array_equal(svc.poll(qid).result, expected)
    # cache hit: served from the same shared (still intact) array
    qid2 = svc.submit("g", "bfs", src)
    hit = svc.poll(qid2)
    assert hit.from_cache
    np.testing.assert_array_equal(hit.result, expected)
    with pytest.raises(ValueError):
        hit.result[:] = 0


def test_cache_put_freezes_array():
    """ResultCache.put publishes the array as shared state: the same
    object comes back from get, read-only."""
    cache = ResultCache(capacity=4)
    arr = np.arange(5, dtype=np.int32)
    cache.put("g", "bfs", 0, CFG, arr)
    got = cache.get("g", "bfs", 0, CFG)
    assert got is arr
    with pytest.raises(ValueError):
        got[0] = 99
    np.testing.assert_array_equal(cache.get("g", "bfs", 0, CFG),
                                  np.arange(5))


def test_follower_results_are_readonly(rmat_g):
    """Coalesced followers receive the shared primary array — also
    frozen."""
    svc = QueryService(num_slots=1, cfg=CFG)
    svc.register_graph("g", rmat_g)
    src = _sources(rmat_g, 1, seed=13)[0]
    qid1 = svc.submit("g", "bfs", src)
    qid2 = svc.submit("g", "bfs", src)      # coalesces onto qid1
    svc.run()
    r1, r2 = svc.poll(qid1).result, svc.poll(qid2).result
    assert r1 is r2
    with pytest.raises(ValueError):
        r2[0] = 1


# ---------------------------------------------------------------------------
# traversal direction through the serving engine (DESIGN.md section 9)
# ---------------------------------------------------------------------------

def test_served_query_matches_standalone_adaptive_direction(rmat_g):
    """A service configured with adaptive direction still serves every
    query bitwise equal to its standalone (push) run, and the direction
    field keeps cache entries of different direction configs apart."""
    adaptive_cfg = BalancerConfig(strategy="alb", threshold=32,
                                  direction="adaptive")
    svc = QueryService(num_slots=2, cfg=adaptive_cfg)
    svc.register_graph("g", rmat_g)
    sources = _sources(rmat_g, 3, seed=17)
    qids = [svc.submit("g", "bfs", s) for s in sources]
    svc.run()
    for s, qid in zip(sources, qids):
        ref = np.asarray(bfs(rmat_g, s, CFG).labels)
        np.testing.assert_array_equal(svc.poll(qid).result, ref)
    assert svc.cache.key("g", "bfs", sources[0], adaptive_cfg) \
        != svc.cache.key("g", "bfs", sources[0], CFG)


# ---------------------------------------------------------------------------
# Streaming updates through the service (DESIGN.md section 10).
# ---------------------------------------------------------------------------

def _two_component_graph():
    """Two disjoint 10-vertex cycles: queries from component A (0-9)
    can never reach component B (10-19), so their cached regions are
    provably disjoint."""
    src, dst = [], []
    for base in (0, 10):
        for i in range(10):
            src.append(base + i)
            dst.append(base + (i + 1) % 10)
    from repro.core import streaming as S
    return S.streaming_graph(
        G.from_edge_list(np.asarray(src), np.asarray(dst), 20))


def test_region_tagged_eviction_preserves_hit_rate_floor():
    """A streaming update inside component B evicts B-region entries
    but KEEPS component-A entries: the post-update resubmission of the
    A query is a cache hit (the hit-rate floor), while the B query is
    recomputed against the new topology."""
    from repro.core import streaming as S
    from repro.core.apps import bfs as bfs_app

    g = _two_component_graph()
    svc = QueryService(num_slots=4, cfg=CFG)
    svc.register_graph("g", g)
    qa0 = svc.submit("g", "bfs", 0)       # component A
    qb0 = svc.submit("g", "bfs", 10)      # component B
    svc.run()
    assert len(svc.cache) == 2

    # mutate inside component B only
    evicted = svc.apply_updates(
        "g", S.make_batch([("insert", 15, 17, 1)]))
    assert evicted == 1                   # B evicted, A survived
    assert len(svc.cache) == 1

    qa1 = svc.submit("g", "bfs", 0)
    qb1 = svc.submit("g", "bfs", 10)
    svc.run()
    assert svc.poll(qa1).from_cache       # the hit-rate floor
    assert not svc.poll(qb1).from_cache   # intersecting entry evicted
    g2 = svc._graphs["g"]
    nv = S.real_vertices(g2)
    for qid, s in ((qa1, 0), (qb1, 10)):
        ref = np.asarray(bfs_app(g2, s, CFG).labels)[:nv]
        np.testing.assert_array_equal(
            np.asarray(svc.poll(qid).result)[:nv], ref)
    # and the surviving entry really is byte-identical to a fresh run
    assert svc.poll(qa1).result is svc.poll(qa0).result


def test_untagged_entries_evicted_conservatively():
    """Entries without a region tag (e.g. put directly) are evicted by
    ANY delta — correctness never depends on the tag being present."""
    cache = ResultCache(capacity=8)
    lab = np.zeros(20, np.int32)
    cache.put("g", "bfs", 0, CFG, lab)                  # no region
    cache.put("g", "bfs", 1, CFG, np.ones(20, np.int32),
              region=np.zeros(20, bool))                # empty region
    assert cache.invalidate_delta("g", [5]) == 1        # untagged dies
    assert cache.get("g", "bfs", 1, CFG) is not None    # tagged lives


def test_single_flight_keys_on_graph_version():
    """A submitter arriving AFTER apply_updates never coalesces onto a
    pre-update in-flight computation: the stale primary answers only
    its pre-update submitters (snapshot isolation), and the new
    submitter is computed on the new topology."""
    from repro.core import streaming as S
    from repro.core.apps import sssp as sssp_app

    g = S.streaming_graph(G.rmat(6, 4, seed=2))
    svc = QueryService(num_slots=2, cfg=CFG)
    svc.register_graph("g", g)

    qa = svc.submit("g", "sssp", 0)       # primary, version 0
    qa2 = svc.submit("g", "sssp", 0)      # coalesces onto qa
    assert svc.poll(qa2).status == QUEUED
    svc.step()                            # qa now running

    snapshot = svc._banks[("g", "sssp")].g
    svc.apply_updates("g", S.make_batch([("insert", 0, 9, 1)]))
    qb = svc.submit("g", "sssp", 0)       # same query, NEW version
    assert svc.poll(qb).version == svc._graphs["g"].version
    assert svc.poll(qb).version != svc.poll(qa).version
    svc.run()

    nv = S.real_vertices(g)
    ref_old = np.asarray(sssp_app(snapshot, 0, CFG).labels)[:nv]
    ref_new = np.asarray(sssp_app(svc._graphs["g"], 0, CFG).labels)[:nv]
    assert not np.array_equal(ref_old, ref_new)  # update was visible
    np.testing.assert_array_equal(
        np.asarray(svc.poll(qa).result)[:nv], ref_old)
    np.testing.assert_array_equal(          # follower got qa's labels
        np.asarray(svc.poll(qa2).result)[:nv], ref_old)
    assert svc.poll(qa2).from_cache
    np.testing.assert_array_equal(          # post-update submitter: new
        np.asarray(svc.poll(qb).result)[:nv], ref_new)
    assert not svc.poll(qb).from_cache


def test_stale_bank_drains_and_is_replaced():
    """apply_updates while a bank is busy: the bank finishes its
    occupants on the old snapshot (no admissions, no preemptions),
    then disappears; queued work admits into a fresh bank bound to the
    new version, and results cached during the drain never poison the
    new version's cache."""
    from repro.core import streaming as S

    g = S.streaming_graph(G.rmat(6, 4, seed=2))
    svc = QueryService(num_slots=1, cfg=CFG)   # force queueing
    svc.register_graph("g", g)
    qa = svc.submit("g", "bfs", 0)
    qb = svc.submit("g", "bfs", 1)             # waits for the one slot
    svc.step()                                 # qa admitted
    svc.apply_updates("g", S.make_batch([("insert", 1, 2, 1)]))
    bank = svc._banks[("g", "bfs")]
    assert bank.stale and bank.busy() == 1
    svc.run()
    # qa drained on the snapshot; its result was NOT cached (stale
    # version) — only qb, computed on the new graph, was
    assert svc.poll(qa).status == DONE
    assert svc.poll(qb).status == DONE
    assert svc.cache.get("g", "bfs", 1, CFG) is not None
    got = svc.cache.get("g", "bfs", 0, CFG)
    assert got is None or svc.poll(qb).version == svc._graphs["g"].version
    # the replacement bank is bound to the current graph version
    assert svc._banks[("g", "bfs")].g.version == svc._graphs["g"].version


def test_queued_query_rebinds_to_new_version_at_admission():
    """A query submitted pre-update but admitted post-update computes
    on the NEW graph (late binding) and its result is cacheable for
    the new version."""
    from repro.core import streaming as S
    from repro.core.apps import bfs as bfs_app

    g = S.streaming_graph(G.rmat(6, 4, seed=2))
    svc = QueryService(num_slots=1, cfg=CFG)
    svc.register_graph("g", g)
    qa = svc.submit("g", "bfs", 0)
    qb = svc.submit("g", "bfs", 3)             # queued behind qa
    svc.step()
    svc.apply_updates("g", S.make_batch([("insert", 3, 5, 1)]))
    svc.run()
    assert svc.poll(qb).version == svc._graphs["g"].version
    nv = S.real_vertices(g)
    ref = np.asarray(bfs_app(svc._graphs["g"], 3, CFG).labels)[:nv]
    np.testing.assert_array_equal(
        np.asarray(svc.poll(qb).result)[:nv], ref)
    # and a repeat is a hit on the new version
    qc = svc.submit("g", "bfs", 3)
    assert svc.poll(qc).from_cache
