"""Mamba2 (SSD — state-space duality) block, chunked, decode-capable.

Implements the SSD recurrence  h_t = a_t * h_{t-1} + dt_t * B_t x_t^T,
y_t = C_t h_t  with scalar-per-head decay a_t = exp(-dt_t * A_h), via
the chunked matrix formulation of arXiv:2405.21060: intra-chunk terms
are batched matmuls (MXU-friendly), inter-chunk state is a short scan
over chunks.  Sub-quadratic: compute O(S * chunk), state O(H*P*N).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import COMPUTE_DTYPE, _dense_init, rms_norm


def ssm_dims(cfg):
    d_inner = cfg.ssm.expand * cfg.d_model
    nheads = d_inner // cfg.ssm.head_dim
    return d_inner, nheads


def mamba2_init(key, cfg):
    d = cfg.d_model
    s = cfg.ssm
    d_inner, nheads = ssm_dims(cfg)
    ks = jax.random.split(key, 6)
    # fused input projection: [z (gate), x, B, C, dt]
    d_proj = 2 * d_inner + 2 * s.d_state + nheads
    return {
        "w_in": _dense_init(ks[0], (d, d_proj)),
        "conv_w": _dense_init(ks[1], (s.d_conv, d_inner + 2 * s.d_state),
                              scale=1.0 / math.sqrt(s.d_conv)),
        "conv_b": jnp.zeros((d_inner + 2 * s.d_state,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads,
                                      dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "out_norm": jnp.zeros((d_inner,), jnp.float32),
        "w_out": _dense_init(ks[2], (d_inner, d)),
    }


def _split_proj(cfg, proj):
    d_inner, nheads = ssm_dims(cfg)
    n = cfg.ssm.d_state
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * n]
    dt = proj[..., -nheads:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """depthwise causal conv over time. xbc: [B, S, C].

    conv_state: [B, d_conv-1, C] trailing context for decode; returns
    (out, new_conv_state)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(k):                      # tiny k (4): unrolled taps
        out = out + xp[:, i:i + xbc.shape[1]] * conv_w[i].astype(xbc.dtype)
    out = out + conv_b.astype(xbc.dtype)
    new_state = xp[:, xp.shape[1] - (k - 1):]
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """SSD scan, chunked matrix form.

    x: [B, S, H, P]; dt: [B, S, H]; b, c: [B, S, N].
    Returns y: [B, S, H, P] and final state [B, H, P, N].
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    nch = -(-s // chunk)
    pad = nch * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad dt with -inf-ish so softplus(dt)=0: padded steps must be
        # IDENTITY in the recurrence (decay exp(0)=1, contribution 0),
        # otherwise the final state hT picks up spurious decay
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-1e4)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    a = -jnp.exp(a_log.astype(jnp.float32))               # [H], negative
    dt = jax.nn.softplus(dt.astype(jnp.float32))          # [B, S', H]
    # log decay per step: la[t] = dt[t] * a  (<= 0)
    la = dt * a[None, None, :]

    xc = (x.astype(jnp.float32)
          * dt[..., None]).reshape(bsz, nch, chunk, h, p)
    bc = b.astype(jnp.float32).reshape(bsz, nch, chunk, n)
    cc = c.astype(jnp.float32).reshape(bsz, nch, chunk, n)
    lac = la.reshape(bsz, nch, chunk, h)

    # cumulative log decay within chunk (inclusive)
    cum = jnp.cumsum(lac, axis=2)                          # [B,Nc,L,H]

    # ---- intra-chunk (dual / attention-like quadratic within chunk) ----
    # decay(tq, tk) = exp(cum[tq] - cum[tk]) for tq >= tk
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [B,Nc,L,L,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: upper-triangle rel is large-positive and exp(rel)
    # would be inf, poisoning the where() gradient (inf * 0 = nan)
    rel = jnp.where(tri[None, None, :, :, None], rel, -jnp.inf)
    gamma = jnp.exp(rel)
    scores = jnp.einsum("bzqn,bzkn->bzqk", cc, bc)         # [B,Nc,L,L]
    y_intra = jnp.einsum("bzqk,bzqkh,bzkhp->bzqhp",
                         scores, gamma, xc)

    # ---- chunk states + inter-chunk scan ----
    # state contribution of chunk: sum_k exp(cum[L-1]-cum[k]) * B_k x_k
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                # [B,Nc,L,H]
    states = jnp.einsum("bzkh,bzkn,bzkhp->bzhpn", tail, bc, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [B,Nc,H]

    def scan_fn(h_prev, inp):
        st, dec = inp
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    hT, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # [B,Nc,H,P,N]

    # ---- inter-chunk output: y += C_t exp(cum[t]) h_prev ----
    y_inter = jnp.einsum("bzqn,bzqh,bzhpn->bzqhp",
                         cc, jnp.exp(cum), h_prevs)

    y = (y_intra + y_inter).reshape(bsz, nch * chunk, h, p)
    if pad:
        y = y[:, :s]
    return y.astype(x.dtype), hT


def ssd_step(h_state, x, dt, a_log, b, c):
    """Single decode step. x: [B, H, P]; b, c: [B, N]; dt: [B, H].
    h_state: [B, H, P, N] -> returns (y [B,H,P], new state)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    decay = jnp.exp(dt * a[None, :])                        # [B,H]
    xb = jnp.einsum("bhp,bn->bhpn", x.astype(jnp.float32) * dt[..., None],
                    b.astype(jnp.float32))
    h_new = h_state * decay[..., None, None] + xb
    y = jnp.einsum("bhpn,bn->bhp", h_new, c.astype(jnp.float32))
    return y.astype(x.dtype), h_new


def mamba2_apply(p, x, cfg, *, state=None, return_state=False):
    """x: [B, S, D].  state: None (training/prefill from scratch) or
    dict {h: [B,H,P,N], conv: [B,d_conv-1,C]} for decode.
    return_state: emit the final state even when starting stateless
    (prefill).  Returns (out, new_state)."""
    bsz, s, d = x.shape
    scfg = cfg.ssm
    d_inner, nheads = ssm_dims(cfg)
    n, pdim = scfg.d_state, scfg.head_dim
    xc = x.astype(COMPUTE_DTYPE)
    proj = xc @ p["w_in"].astype(COMPUTE_DTYPE)
    z, xbc, dt = _split_proj(cfg, proj)

    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :d_inner].reshape(bsz, s, nheads, pdim)
    b = xbc[..., d_inner:d_inner + n]
    c = xbc[..., d_inner + n:]

    if state is None:
        y, hT = ssd_chunked(xs, dt, p["a_log"], b, c, scfg.chunk)
    else:
        assert s == 1, "stateful path is single-token decode"
        y1, hT = ssd_step(state["h"], xs[:, 0], dt[:, 0], p["a_log"],
                          b[:, 0], c[:, 0])
        y = y1[:, None]
    y = y + xs * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_inner)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"].astype(COMPUTE_DTYPE)
    if state is not None or return_state:
        new_state = {"h": hT, "conv": new_conv.astype(COMPUTE_DTYPE)}
    else:
        new_state = None
    return out.astype(x.dtype), new_state


def mamba2_state_shape(cfg, batch, dtype=jnp.float32):
    d_inner, nheads = ssm_dims(cfg)
    s = cfg.ssm
    return {
        "h": jax.ShapeDtypeStruct(
            (batch, nheads, s.head_dim, s.d_state), jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (batch, s.d_conv - 1, d_inner + 2 * s.d_state), COMPUTE_DTYPE),
    }
