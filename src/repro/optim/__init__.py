from .adamw import adamw_init, adamw_update, OptConfig
from .schedules import wsd_schedule, cosine_schedule

__all__ = ["adamw_init", "adamw_update", "OptConfig",
           "wsd_schedule", "cosine_schedule"]
