"""Graph container + generator invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph as G


def test_csr_from_edge_list_roundtrip():
    src = np.array([0, 0, 1, 3, 3, 3])
    dst = np.array([1, 2, 2, 0, 1, 2])
    g = G.from_edge_list(src, dst, 4)
    assert g.num_vertices == 4
    assert g.num_edges == 6
    np.testing.assert_array_equal(np.asarray(g.row_ptr), [0, 2, 3, 3, 6])
    np.testing.assert_array_equal(np.asarray(g.out_degrees()), [2, 1, 0, 3])


def test_from_edge_list_dedup():
    g = G.from_edge_list(np.array([0, 0, 0]), np.array([1, 1, 2]), 3)
    assert g.num_edges == 2


def test_rmat_power_law():
    g = G.rmat(10, 8, seed=0)
    assert g.num_vertices == 1024
    deg = np.asarray(g.out_degrees())
    # power-law: max degree far above mean
    assert deg.max() > 10 * deg.mean()
    assert int(deg.sum()) == g.num_edges


def test_road_grid_flat_degree():
    g = G.road_grid(16)
    deg = np.asarray(g.out_degrees())
    assert deg.max() <= 4
    assert g.num_vertices == 256


def test_uniform_balanced():
    g = G.uniform_random(1024, 8, seed=0)
    deg = np.asarray(g.out_degrees())
    assert deg.max() < 8 * deg.mean()


def test_reverse_graph_preserves_edges():
    g = G.rmat(8, 4, seed=1)
    rg = G.reverse_graph(g)
    assert rg.num_edges == g.num_edges
    # reversing twice restores the out-degree multiset
    rrg = G.reverse_graph(rg)
    np.testing.assert_array_equal(
        np.sort(np.asarray(rrg.out_degrees())),
        np.sort(np.asarray(g.out_degrees())))


def test_pad_graph_alignment_and_semantics():
    g = G.rmat(7, 3, seed=2)
    gp = G.pad_graph(g, v_multiple=8, e_multiple=1024)
    assert gp.num_vertices % 8 == 0
    assert gp.num_edges % 1024 == 0
    # padded vertices have degree 0
    deg = np.asarray(gp.out_degrees())
    assert (deg[g.num_vertices:] == 0).all()
    # real structure unchanged
    np.testing.assert_array_equal(np.asarray(gp.row_ptr[: g.num_vertices + 1]),
                                  np.asarray(g.row_ptr))


def test_pad_graph_padded_edges_target_padded_vertex():
    """Regression: with V already aligned but E padded, padded col_idx
    entries used to point at the REAL vertex V-1 — a weight-ignoring
    operator walking the padded edge span would corrupt its label.
    Padded edges must target a padded (degree-0, never-read) vertex."""
    g = G.rmat(7, 3, seed=2)          # V=128 is a multiple of 8
    assert g.num_vertices % 8 == 0 and g.num_edges % 1024 != 0
    gp = G.pad_graph(g, v_multiple=8, e_multiple=1024)
    assert gp.num_vertices > g.num_vertices    # vp forced past V
    padded_dst = np.asarray(gp.col_idx[g.num_edges:])
    assert (padded_dst >= g.num_vertices).all()
    assert (padded_dst < gp.num_vertices).all()


def test_pad_graph_cc_edge_lb_unharmed():
    """cc (weight-ignoring, min-combine) via the edge-balanced path on
    an aligned-V / padded-E graph must leave real labels identical to
    the unpadded run — the satellite regression for the padded-edge
    target fix."""
    from repro.core.apps import cc
    from repro.core.balancer import BalancerConfig
    g = G.symmetrized(G.rmat(7, 3, seed=2))
    assert g.num_vertices % 8 == 0
    gp = G.pad_graph(g, v_multiple=8, e_multiple=1024)
    assert gp.num_edges > g.num_edges
    cfg = BalancerConfig(strategy="edge_lb", threshold=64)
    ref = cc(g, cfg)
    for mode in ["host", "spmd"]:
        out = cc(gp, cfg, mode=mode)
        np.testing.assert_array_equal(
            np.asarray(out.labels[: g.num_vertices]),
            np.asarray(ref.labels), err_msg=mode)


def test_symmetrized_preserves_weights():
    """Regression: symmetrized() used to drop weights, silently turning
    weighted SSSP on symmetrized inputs into unit-weight BFS."""
    src = np.array([0, 1, 2, 0])
    dst = np.array([1, 2, 0, 2])
    w = np.array([7, 3, 9, 5])
    g = G.from_edge_list(src, dst, 3, weights=w)
    sg = G.symmetrized(g)
    ssrc, sdst, sw = G.to_coo(sg)
    wmap = {(int(a), int(b)): int(x) for a, b, x in zip(ssrc, sdst, sw)}
    # both directions exist and carry the min over duplicates
    assert wmap[(0, 1)] == wmap[(1, 0)] == 7
    assert wmap[(1, 2)] == wmap[(2, 1)] == 3
    # (0,2)/(2,0): forward weight 5, reverse of (2,0) weight 9 -> min 5
    assert wmap[(0, 2)] == wmap[(2, 0)] == 5
    # round-trip: symmetrizing a symmetric graph is the identity
    s2 = G.symmetrized(sg)
    np.testing.assert_array_equal(np.asarray(s2.row_ptr),
                                  np.asarray(sg.row_ptr))
    np.testing.assert_array_equal(np.asarray(s2.col_idx),
                                  np.asarray(sg.col_idx))
    np.testing.assert_array_equal(np.asarray(s2.edge_w),
                                  np.asarray(sg.edge_w))


def test_from_edge_list_dedup_keeps_min_weight():
    """Regression: dedup used to keep an input-order-dependent
    duplicate's weight; it must keep the per-(src, dst) minimum,
    independent of edge order."""
    src = np.array([0, 0, 0, 0])
    dst = np.array([1, 1, 1, 2])
    w = np.array([9, 2, 5, 4])
    g = G.from_edge_list(src, dst, 3, weights=w)
    assert g.num_edges == 2
    np.testing.assert_array_equal(np.asarray(g.edge_w), [2, 4])
    # permuting the input edges changes nothing
    perm = np.array([2, 3, 0, 1])
    g2 = G.from_edge_list(src[perm], dst[perm], 3, weights=w[perm])
    np.testing.assert_array_equal(np.asarray(g.col_idx),
                                  np.asarray(g2.col_idx))
    np.testing.assert_array_equal(np.asarray(g.edge_w),
                                  np.asarray(g2.edge_w))


def test_highest_out_degree_vertex():
    g = G.rmat(8, 8, seed=0)
    v = G.highest_out_degree_vertex(g)
    deg = np.asarray(g.out_degrees())
    assert deg[v] == deg.max()


# ---------------------------------------------------------------------------
# Versioned memoization (DESIGN.md section 10).
# ---------------------------------------------------------------------------

def test_reverse_cache_invalidated_by_version_bump():
    """Regression: ``reverse()`` used to memoize with no invalidation
    hook, so an in-place topology change kept serving the OLD
    transpose.  The cache is now keyed on ``Graph.version``."""
    from repro.core import streaming as S
    g = S.streaming_graph(G.rmat(5, 4, seed=2))
    rg_before = g.reverse()
    assert g.reverse() is rg_before            # memoized while static
    far = int(np.argmax(np.asarray(g.col_idx)[:1]))  # any real vertex
    S.apply_updates(g, S.make_batch([("insert", 0, 1, 7)]),
                    in_place=True)
    rg_after = g.reverse()
    assert rg_after is not rg_before
    # the new transpose must contain the inserted edge reversed
    em = S.edge_map(rg_after)
    assert em.get((1, 0)) == 7 or (1, 0) in em


def test_pull_after_mutation_matches_push():
    """Regression for the stale ``_pull_enum`` hazard: a pull-direction
    run AFTER an in-place mutation must agree with push on the mutated
    graph (it used to traverse the pre-mutation enumeration)."""
    from repro.core import streaming as S
    from repro.core.balancer import BalancerConfig
    from repro.core.apps import drivers

    g = S.streaming_graph(G.rmat(5, 4, seed=2))
    push = BalancerConfig(strategy="alb", threshold=64,
                          direction="push")
    pull = BalancerConfig(strategy="alb", threshold=64,
                          direction="pull")
    # populate both the reverse() and _pull_enum caches pre-mutation
    drivers.bfs(g, 0, pull)
    # mutate in place: add a shortcut that changes bfs levels
    lab0 = np.asarray(drivers.bfs(g, 0, push).labels)
    far = int(np.argmax(lab0[: S.real_vertices(g)]))
    S.apply_updates(g, S.make_batch([("insert", 0, far, 1)]),
                    in_place=True)
    got_pull = np.asarray(drivers.bfs(g, 0, pull).labels)
    got_push = np.asarray(drivers.bfs(g, 0, push).labels)
    nv = S.real_vertices(g)
    np.testing.assert_array_equal(got_pull[:nv], got_push[:nv])
    assert got_pull[far] == 1                  # the mutation took


def test_version_starts_at_zero_and_bumps():
    g = G.rmat(4, 4, seed=0)
    assert g.version == 0
    g.bump_version()
    assert g.version == 1
    # pytree round-trips never carry the version (it lives outside
    # the flattened leaves, so jit cache keys are unaffected)
    import jax
    leaves, treedef = jax.tree_util.tree_flatten(g)
    g2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert g2.version == 0
