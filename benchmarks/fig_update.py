"""Incremental recompute vs full recompute under streaming updates
(DESIGN.md section 10).

A live serving deployment absorbs edge updates continuously; the
question this harness answers is how much relax work the incremental
repair path (``stream_update``: seed the frontier from changed edges,
resume the round loop) saves over recomputing every query from
scratch.  For each graph class we replay an insert-only trace and a
mixed insert/delete/reweight trace, reporting per-update rounds and
wall clock for both policies, plus how often the mixed trace fell back
to a full recompute.

Rows: ``update_<app>_<graph>_<trace>_<policy>,us_per_update,
rounds_per_update=R [fallback_share=F]``.

Run directly (also wired as the ``update`` selector of
benchmarks.run):

    PYTHONPATH=src python -m benchmarks.fig_update          # sweep
    PYTHONPATH=src python -m benchmarks.fig_update --smoke  # CI

``--smoke`` shrinks the inputs and gates on STRUCTURAL invariants only
(never wall clock):

1. parity — after every batch of every trace, the incremental labels
   are bitwise equal to a from-scratch run on the mutated graph;
2. work — on the insert-only traces, total incremental repair rounds
   never exceed total full-recompute rounds (inserts never trigger
   the delete fallback, so repair must be pure savings).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import graph as G
from repro.core import streaming as S
from repro.core.balancer import BalancerConfig

from .common import emit

APPS = ["bfs", "sssp"]


def _inputs(smoke: bool) -> dict:
    if smoke:
        return {"rmat": G.rmat(8, 6, seed=1),
                "road": G.road_grid(12, seed=1)}
    return {"rmat": G.rmat(11, 8, seed=1),
            "road": G.road_grid(40, seed=1)}


def _traces(g: G.Graph, smoke: bool) -> dict:
    """Two traces per graph: insert-only (pure improvements — the
    incremental sweet spot) and mixed (deletes/reweights included, so
    the tight-edge fallback gets exercised).  Batches are built at one
    capacity so the whole trace reuses one seeding-scatter shape."""
    rng = np.random.default_rng(7)
    nv = g.num_vertices
    n_batches, per_batch, cap = (4, 8, 16) if smoke else (12, 24, 32)
    edges = dict(S.edge_map(g))

    inserts = []
    for _ in range(n_batches):
        ups = []
        while len(ups) < per_batch:
            u, v = int(rng.integers(nv)), int(rng.integers(nv))
            ups.append(("insert", u, v, int(rng.integers(1, 20))))
        inserts.append(S.make_batch(ups, capacity=cap))

    mixed = []
    for _ in range(n_batches):
        ups = []
        for _ in range(per_batch):
            r = float(rng.random())
            keys = list(edges)
            if r < 0.5 or not keys:
                u, v = int(rng.integers(nv)), int(rng.integers(nv))
                ups.append(("insert", u, v, int(rng.integers(1, 20))))
                edges[(u, v)] = min(edges.get((u, v), 99),
                                    int(ups[-1][3]))
            elif r < 0.75:
                u, v = keys[int(rng.integers(len(keys)))]
                ups.append(("delete", u, v))
                edges.pop((u, v), None)
            else:
                u, v = keys[int(rng.integers(len(keys)))]
                w = int(rng.integers(1, 20))
                ups.append(("reweight", u, v, w))
                edges[(u, v)] = w
        mixed.append(S.make_batch(ups, capacity=cap))
    return {"ins": inserts, "mix": mixed}


def _replay(g0, app, cfg, batches, incremental: bool):
    """Run one (policy, trace) cell: returns (labels_after_each_batch,
    total_rounds, total_seconds, fallbacks).  The full-recompute
    policy still routes updates through apply_updates (same fixed-shape
    CSR path) but recomputes labels from scratch every batch."""
    src = None if app == "cc" else G.highest_out_degree_vertex(g0)
    st = S.stream_init(S.streaming_graph(g0), app, source=src, cfg=cfg)
    labels_seq, rounds, fallbacks = [], 0, 0
    t0 = time.perf_counter()
    for batch in batches:
        if incremental:
            rep = S.stream_update(st, batch)
            rounds += rep.rounds
            fallbacks += int(rep.full_recompute)
        else:
            st.g = S.apply_updates(st.g, batch)
            res = S._full_compute(st.g, app, src, cfg, st.mode)
            st.labels = res.labels
            rounds += res.rounds
        labels_seq.append(st.real_labels.copy())
    return labels_seq, rounds, time.perf_counter() - t0, fallbacks


def run(smoke: bool = False) -> int:
    cfg = BalancerConfig(strategy="alb", threshold=64)
    failures = 0
    for gname, g in _inputs(smoke).items():
        traces = _traces(g, smoke)
        for app in APPS:
            for tname, batches in traces.items():
                cells = {}
                for policy, inc in (("incr", True), ("full", False)):
                    labels, rounds, secs, fb = _replay(
                        g, app, cfg, batches, incremental=inc)
                    cells[policy] = (labels, rounds, fb)
                    per_update = rounds / len(batches)
                    extra = f"rounds_per_update={per_update:.1f}"
                    if inc and tname == "mix":
                        extra += (f" fallback_share="
                                  f"{fb / len(batches):.2f}")
                    emit(f"update_{app}_{gname}_{tname}_{policy}",
                         secs / len(batches), extra)
                # ---- structural gates (no wall clock) ----------------
                inc_l, inc_r, _ = cells["incr"]
                full_l, full_r, _ = cells["full"]
                for i, (a, b) in enumerate(zip(inc_l, full_l)):
                    if not np.array_equal(a, b):
                        print(f"FAIL: {app}/{gname}/{tname} batch {i}: "
                              f"incremental labels != full recompute",
                              file=sys.stderr)
                        failures += 1
                if tname == "ins" and inc_r > full_r:
                    print(f"FAIL: {app}/{gname}/ins: incremental took "
                          f"{inc_r} rounds > full's {full_r}",
                          file=sys.stderr)
                    failures += 1
    return failures


def main() -> int:
    smoke = "--smoke" in sys.argv[1:]
    failures = run(smoke=smoke)
    if failures:
        return 1
    if smoke:
        print("smoke OK: incremental/full parity + insert-trace rounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
