"""The single choke point for publishing arrays as shared state.

Every ndarray that leaves the serving layer's private buffers —
``ResultCache`` entries, ``poll().result``, anything hung off
``ServiceStats`` — is aliased, not copied: the same object is handed
to every cache hit and every coalesced follower.  :func:`freeze`
makes that safe by marking the array read-only before publication,
so an in-place mutation by any caller raises instead of silently
corrupting every other caller's answer.

The static publish-freeze pass (``repro.analysis``) enforces that
stores into those sinks flow through this helper; keeping it a
one-liner in its own module is what makes that enforcement textual.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def freeze(arr: Optional[np.ndarray]) -> Optional[np.ndarray]:
    """Mark ``arr`` read-only (``setflags(write=False)``) and return
    it; ``None`` passes through for optional fields."""
    if arr is not None:
        arr.setflags(write=False)
    return arr
