"""LRU result cache of the serving layer.

Point queries are deterministic — the batched engine guarantees every
served query is bitwise equal to its standalone run — so a repeat
(graph, app, source) lookup can be answered from memory without
touching the device.  Keys are ``(graph_id, app, source, strategy)``
where ``strategy`` is the frozen :class:`BalancerConfig` (hashable by
construction): results are strategy-independent by the parity
invariant, but keying on the config keeps the cache trivially correct
if a future strategy ever trades exactness for speed, and lets A/B
deployments coexist (DESIGN.md section 8).

Re-registering a graph id invalidates every entry for that id — the
binding ``graph_id -> CSR`` changed, so cached labels may be stale.
Streaming updates (DESIGN.md section 10) are finer-grained: each entry
may carry a **region tag** — the query's reachable set ``labels <
INF`` — and :meth:`invalidate_delta` evicts only entries whose region
intersects the update's changed-edge sources.  An edge change at
``(u, v)`` can alter labels-from-``s`` only if ``u`` is reachable from
``s``, so an entry whose tag misses every changed source provably
still holds for the NEW graph version and survives the bump — the
serving hit rate never resets to zero on a localized mutation.

Published arrays are **read-only**: ``put`` freezes the ndarray
(``setflags(write=False)``) before it becomes shared state.  The same
object is handed to every future ``get`` — and, via the engine, to the
primary's ``poll().result`` and all coalesced followers — so a caller
mutating a result in place would otherwise silently corrupt every
future cache hit.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional

import numpy as np

from .publish import freeze


class ResultCache:
    """Bounded LRU map ``(graph_id, app, source, strategy) ->
    labels[V]`` with hit/miss counters; ``capacity=0`` disables
    caching entirely (every ``get`` is a miss)."""

    def __init__(self, capacity: int = 256) -> None:
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(graph_id: str, app: str, source: int,
            strategy: Hashable) -> tuple:
        """The canonical cache key (DESIGN.md section 8)."""
        return (graph_id, app, int(source), strategy)

    def get(self, graph_id: str, app: str, source: int,
            strategy: Hashable) -> Optional[np.ndarray]:
        """Cached labels for the query, refreshing its LRU position;
        None (and a counted miss) when absent."""
        k = self.key(graph_id, app, source, strategy)
        if k not in self._entries:
            self.misses += 1
            return None
        self._entries.move_to_end(k)
        self.hits += 1
        return self._entries[k][0]

    def put(self, graph_id: str, app: str, source: int,
            strategy: Hashable, labels: np.ndarray,
            region: Optional[np.ndarray] = None) -> None:
        """Insert/refresh an entry, evicting the least recently used
        entry when over capacity.  The array is frozen
        (``setflags(write=False)``) — it becomes shared state served to
        every future hit, so in-place mutation must raise rather than
        corrupt the cache.

        ``region`` optionally tags the entry with the query's
        reachability summary (``bool[V]``, typically ``labels < INF``)
        for :meth:`invalidate_delta`; an untagged entry is treated as
        reaching everywhere, i.e. evicted by every delta."""
        if self.capacity == 0:
            return
        labels = freeze(labels)
        if region is not None:
            region = freeze(np.asarray(region, dtype=bool))
        k = self.key(graph_id, app, source, strategy)
        self._entries[k] = (labels, region)
        self._entries.move_to_end(k)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def invalidate_graph(self, graph_id: str) -> int:
        """Drop every entry of ``graph_id`` (its CSR binding changed);
        returns how many entries were dropped."""
        stale = [k for k in self._entries if k[0] == graph_id]
        for k in stale:
            del self._entries[k]
        return len(stale)

    def invalidate_delta(self, graph_id: str, delta_vertices) -> int:
        """Fine-grained streaming eviction (DESIGN.md section 10):
        drop only the ``graph_id`` entries whose region tag intersects
        ``delta_vertices`` (the changed-edge source endpoints, e.g.
        ``NetDelta.sources()``).  Entries without a region tag are
        conservatively evicted; entries whose tag misses every delta
        vertex remain valid for the mutated graph and are KEPT.
        Returns how many entries were dropped."""
        delta = np.asarray(list(delta_vertices), dtype=np.int64)
        stale = []
        for k, (_, region) in self._entries.items():
            if k[0] != graph_id:
                continue
            if region is None or (len(delta) and
                                  bool(region[delta].any())):
                stale.append(k)
        for k in stale:
            del self._entries[k]
        return len(stale)

    @property
    def hit_rate(self) -> float:
        """hits / (hits + misses); 0.0 before any lookup."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._entries)
