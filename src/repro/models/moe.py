"""Mixture-of-Experts layer with ALB-adaptive dispatch.

The router's tokens-per-expert histogram is the LM-stack analogue of
the paper's edges-per-vertex distribution: a few hot experts receive
orders of magnitude more tokens (power-law routing), and a static
capacity truncation (the "blocked" baseline) silently drops the
overflow.  Following DESIGN.md section 5, the dispatch applies the
paper's inspector-executor split:

* inspector: per-step expert load histogram; if max load <= capacity
  nothing extra runs (``lax.cond`` — the adaptive part);
* executor: overflow tokens are re-dealt to their next-best expert via
  the same prefix-sum + position-renumbering machinery the graph LB
  kernel uses (kernels/moe_dispatch.py holds the Pallas fast path).

Experts are sharded over the ``model`` mesh axis (expert parallelism);
the einsum formulation keeps the dispatch compilable under pjit.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .layers import COMPUTE_DTYPE, _dense_init, mlp_init, mlp_apply


def moe_init(key, cfg):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "router": _dense_init(ks[0], (d, m.num_experts)),
        # stacked expert FFNs: [E, ...]
        "w_gate": _dense_init(ks[1], (m.num_experts, d, m.d_expert)),
        "w_up": _dense_init(ks[2], (m.num_experts, d, m.d_expert)),
        "w_down": _dense_init(ks[3], (m.num_experts, m.d_expert, d)),
    }
    if m.num_shared_experts:
        kk = jax.random.split(jax.random.fold_in(key, 99), 1)[0]
        p["shared"] = mlp_init(kk, d, m.d_expert * m.num_shared_experts,
                               "silu")
    return p


def _positions_in_expert(expert_of, num_experts):
    """pos[i] = rank of assignment i within its expert (arrival order).

    The pure-jnp oracle of the position computation; see
    kernels/moe_dispatch.py for the Pallas tile-scan version.
    """
    onehot = jax.nn.one_hot(expert_of, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot              # exclusive
    return jnp.take_along_axis(pos, expert_of[:, None], axis=1)[:, 0]


def dispatch_plan(probs, m, t, *, use_pallas_dispatch: bool = False):
    """Routing plan: (flat_expert, pos, gate_flat, keep, cap).

    Separated from moe_apply so tests / the serving planner can inspect
    drop behaviour directly.
    """
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)    # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    cap = _cap_of(m, t)

    flat_expert = gate_idx.reshape(-1)                     # [T*K]
    if use_pallas_dispatch:
        from repro.kernels.moe_dispatch import positions_in_expert_kernel
        pos = positions_in_expert_kernel(flat_expert, m.num_experts)
    else:
        pos = _positions_in_expert(flat_expert, m.num_experts)

    gate_flat = gate_vals.reshape(-1)                      # [T*K]
    if m.adaptive:
        # ---- ALB inspector-executor --------------------------------
        # inspector: any expert over capacity?  executor: deal the
        # overflow slots CYCLICALLY across the free capacity of ALL
        # experts via an exclusive prefix sum + searchsorted — the
        # paper's edge-balanced renumbering, with (expert free slots ↔
        # vertex degrees, overflow slot rank ↔ global edge id).
        overflow = pos >= cap

        def rebalance(args):
            flat_e, pos, gate = args
            kept1 = (pos < cap).astype(jnp.int32)
            load = jnp.zeros((m.num_experts,), jnp.int32) \
                .at[flat_e].add(kept1)
            free = cap - load                              # >= 0
            start = jnp.cumsum(free) - free                # exclusive
            total_free = jnp.sum(free)
            ovf_rank = jnp.cumsum(overflow.astype(jnp.int32)) - 1
            j = jnp.searchsorted(start, ovf_rank, side="right") - 1
            j = jnp.clip(j, 0, m.num_experts - 1)
            fits = overflow & (ovf_rank < total_free)
            new_e = jnp.where(fits, j.astype(flat_e.dtype), flat_e)
            new_pos = jnp.where(fits, load[j] + (ovf_rank - start[j]),
                                pos)
            # rerouted slots weight by the router's prob for the expert
            # they actually landed on
            probs_flat = jnp.repeat(probs, m.top_k, axis=0)
            new_gate = jnp.where(
                fits,
                probs_flat[jnp.arange(flat_e.shape[0]), j]
                .astype(gate.dtype),
                gate)
            return new_e, new_pos, new_gate

        flat_expert, pos, gate_flat = jax.lax.cond(
            jnp.any(overflow), rebalance, lambda a: a,
            (flat_expert, pos, gate_flat))

    keep = pos < cap
    return flat_expert, pos, gate_flat, keep, cap


def moe_apply(p, x, cfg, *, use_pallas_dispatch: bool = False,
              shard_fn=lambda name, x: x):
    """x: [B, S, D] -> (out, aux_loss).

    Grouped (GShard-style) dispatch: tokens are split into
    ``m.dispatch_groups`` groups aligned with the data-parallel axis;
    positions/capacity/ALB-rebalance are computed per group so the
    prefix sums never cross shard boundaries (a global cumsum would
    force GSPMD to replicate the whole dispatch/combine path).
    """
    m = cfg.moe
    bsz, s, d = x.shape
    t = bsz * s
    g = m.dispatch_groups
    assert t % g == 0, (t, g)
    tg = t // g
    xf = x.reshape(t, d).astype(COMPUTE_DTYPE)

    logits = (xf @ p["router"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                # [T, E]

    # aux load-balancing loss (Switch-style)
    gate_idx_top1 = jnp.argmax(probs, axis=-1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx_top1, m.num_experts), axis=0)
    aux = m.router_aux_weight * m.num_experts * jnp.sum(me * ce)

    probs_g = probs.reshape(g, tg, m.num_experts)
    if g > 1:
        flat_expert, pos, gate_flat, keep, _ = jax.vmap(
            partial(_plan_static, m=m, t=tg))(probs_g)
        cap = _cap_of(m, tg)
    else:
        flat_expert, pos, gate_flat, keep, cap = dispatch_plan(
            probs, m, t, use_pallas_dispatch=use_pallas_dispatch)
        flat_expert = flat_expert[None]
        pos, gate_flat, keep = pos[None], gate_flat[None], keep[None]
    pos_c = jnp.where(keep, pos, 0)                  # [G, Tg*K]

    # ---- dispatch: scatter tokens into [G, E, C, D] buffers ----------
    xg = shard_fn("moe_tok", xf.reshape(g, tg, d))
    xk = jnp.repeat(xg, m.top_k, axis=1)                   # [G, Tg*K, D]
    xk = shard_fn("moe_tok", jnp.where(keep[..., None], xk, 0)
                  .astype(COMPUTE_DTYPE))

    def scatter_one(fe, pc, xx):
        buf = jnp.zeros((m.num_experts, cap, d), COMPUTE_DTYPE)
        return buf.at[fe, pc].add(xx)

    # vmapped over groups: the batched scatter keeps G a batch dim so
    # GSPMD can shard it on the data axes
    buf = jax.vmap(scatter_one)(flat_expert, pos_c, xk)
    # groups ride the data axis; experts ride the model axis
    buf = shard_fn("moe_buf", buf)

    # ---- expert FFNs (einsum over stacked experts; E sharded) --------
    gate = jax.nn.silu(jnp.einsum(
        "gecd,edf->gecf", buf, p["w_gate"].astype(COMPUTE_DTYPE)))
    up = jnp.einsum("gecd,edf->gecf", buf,
                    p["w_up"].astype(COMPUTE_DTYPE))
    hidden = gate * up
    eout = jnp.einsum("gecf,efd->gecd", hidden,
                      p["w_down"].astype(COMPUTE_DTYPE))   # [G, E, C, D]
    eout = shard_fn("moe_buf", eout)

    # ---- combine: gather expert outputs back to tokens ---------------
    tok_out = jax.vmap(lambda e, fe, pc: e[fe, pc])(
        eout, flat_expert, pos_c)                          # [G, Tg*K, D]
    tok_out = shard_fn("moe_tok", tok_out)
    tok_out = jnp.where(keep[..., None], tok_out, 0)
    w = gate_flat[..., None].astype(COMPUTE_DTYPE)
    combined = jnp.sum(
        (tok_out * w.astype(COMPUTE_DTYPE)).reshape(g, tg, m.top_k, d),
        axis=2)

    combined = combined.reshape(t, d)
    if m.num_shared_experts:
        combined = combined + mlp_apply(p["shared"], xf, "silu")

    return combined.reshape(bsz, s, d).astype(x.dtype), aux


def _cap_of(m, t):
    return max(int(m.capacity_factor * t * m.top_k / m.num_experts), 4)


def _plan_static(probs, m, t):
    return dispatch_plan(probs, m, t)
