"""§Roofline: three-term roofline per (arch × shape) from the dry-run
artifacts in artifacts/dryrun/.

  compute   = FLOPs_per_device / peak_FLOPs            (197 TF bf16)
  memory    = HBM_bytes_per_device / HBM_bw            (819 GB/s)
  collective= collective_bytes_per_device / link_bw    (~50 GB/s/link)

FLOPs / HBM bytes / collective bytes come from the cost-extraction
lowerings (scan-free, depth-extrapolated — see dryrun.cost_extract);
memory-fit comes from the full-depth scanned compile.  MODEL_FLOPS is
the analytic 6·N·D (dense) / 6·N_active·D (MoE) useful-work count.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)

ART = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# analytic parameter / useful-FLOPs model
# ---------------------------------------------------------------------------

def param_counts(cfg):
    """(total_params, active_params) excluding embeddings (standard
    6ND convention counts non-embedding matmul params)."""
    d = cfg.d_model
    if cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm.expand * d
        nheads = d_inner // cfg.ssm.head_dim
        n = cfg.ssm.d_state
        per = (d * (2 * d_inner + 2 * n + nheads)        # w_in
               + cfg.ssm.d_conv * (d_inner + 2 * n)      # conv
               + d_inner * d)                            # w_out
        total = per * cfg.num_layers
        if cfg.family == "hybrid":
            hd = cfg.resolved_head_dim
            shared = (d * cfg.num_heads * hd * 2
                      + d * cfg.num_kv_heads * hd * 2
                      + 3 * d * cfg.d_ff)
            total += shared * (cfg.num_layers // cfg.attn_every)
        return total, total
    hd = cfg.resolved_head_dim
    attn = (d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd
            + cfg.num_heads * hd * d)
    if cfg.attention == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        attn = (d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * cfg.num_heads
                * (m.qk_nope_head_dim + m.v_head_dim)
                + cfg.num_heads * m.v_head_dim * d)
    if cfg.family == "moe":
        e = cfg.moe
        expert = 3 * d * e.d_expert
        routed_total = expert * e.num_experts
        routed_active = expert * e.top_k
        shared = 3 * d * e.d_expert * e.num_shared_experts
        total = (attn + routed_total + shared) * cfg.num_layers
        active = (attn + routed_active + shared) * cfg.num_layers
        return total, active
    mlp = 3 * d * cfg.d_ff if cfg.act == "silu" else 2 * d * cfg.d_ff
    per = attn + mlp
    return per * cfg.num_layers, per * cfg.num_layers


def model_flops(cfg, shape, devices: int) -> float:
    """Per-device useful FLOPs for the step (6·N_active·D train,
    2·N_active·D forward-only serve steps)."""
    total, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens / devices
    tokens = shape.global_batch          # one new token per sequence
    return 2.0 * active * tokens / devices


# ---------------------------------------------------------------------------

def load_artifacts():
    cells = {}
    for f in glob.glob(os.path.join(ART, "*.json")):
        with open(f) as fh:
            d = json.load(fh)
        opts = "-".join(d.get("opts", []))
        key = (d["arch"], d["shape"], d.get("mesh", "16x16"), opts)
        if f.endswith("__cost.json"):
            cells.setdefault(key, {})["cost"] = d
        else:
            cells.setdefault(key, {})["run"] = d
    return cells


def analyze(devices_per_pod: int = 256):
    from repro.configs import get_config, shape_by_name
    cells = load_artifacts()
    rows = []
    for (arch, shape_name, mesh, opts), parts in sorted(cells.items()):
        if mesh != "16x16" or "cost" not in parts:
            continue
        cfg = get_config(arch)
        shape = shape_by_name(shape_name)
        c = parts["cost"]
        flops = c["flops_per_device"]
        hbm = c["hbm_bytes_per_device"]
        coll = c["collective_bytes_per_device"]
        t_c = flops / PEAK_FLOPS
        t_m = hbm / HBM_BW
        t_n = coll / LINK_BW
        dom = max((t_c, "compute"), (t_m, "memory"),
                  (t_n, "collective"))[1]
        mf = model_flops(cfg, shape, devices_per_pod)
        useful = mf / max(flops, 1.0)
        temp = (parts.get("run", {}).get("memory", {})
                .get("temp_size_in_bytes", 0))
        args_b = (parts.get("run", {}).get("memory", {})
                  .get("argument_size_in_bytes", 0))
        rows.append(dict(
            arch=arch, shape=shape_name, opts=opts,
            compute_s=t_c, memory_s=t_m, collective_s=t_n,
            dominant=dom, model_flops=mf, hlo_flops=flops,
            useful_ratio=useful, temp_gb=temp / 1e9,
            args_gb=args_b / 1e9,
            roofline_fraction=t_c / max(t_c, t_m, t_n),
        ))
    return rows


def main():
    rows = analyze()
    hdr = ("arch,shape,opts,compute_s,memory_s,collective_s,dominant,"
           "useful_ratio,roofline_frac,temp_GB,args_GB")
    print(hdr)
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['opts'] or 'baseline'},"
              f"{r['compute_s']:.4f},"
              f"{r['memory_s']:.4f},{r['collective_s']:.4f},"
              f"{r['dominant']},{r['useful_ratio']:.3f},"
              f"{r['roofline_fraction']:.3f},{r['temp_gb']:.1f},"
              f"{r['args_gb']:.2f}")


if __name__ == "__main__":
    main()
