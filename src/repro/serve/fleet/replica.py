"""One engine replica of the fleet (DESIGN.md section 13).

A :class:`ReplicaHandle` wraps a :class:`repro.serve.QueryService`
with the two things the fleet needs on top of the engine API: the
load signals the router scores (assigned load, rounds-remaining
estimate, queue-head age — exported by the engine's fleet-facing
hooks), and execution placement/pacing.  ``device`` pins the
replica's computations to one ``jax.Device`` (replicas spread across
the host's devices by default), and ``throttle=k`` advances the
underlying service only every k-th fleet step — the deterministic
straggler knob the hedging tests and benchmarks use to force a slow
replica without touching wall clock.
"""
from __future__ import annotations

import contextlib
from typing import Optional

from ..engine import QueryService


class ReplicaHandle:
    """A fleet-managed engine replica: id + service + placement."""

    def __init__(self, rid: int, svc: QueryService,
                 device=None, throttle: int = 1) -> None:
        if throttle < 1:
            raise ValueError("throttle must be >= 1")
        self.rid = rid
        self.svc = svc
        self.device = device
        self.throttle = throttle
        self._ticks = 0

    def _ctx(self):
        return (jax_default_device(self.device)
                if self.device is not None
                else contextlib.nullcontext())

    def step(self) -> bool:
        """Advance the replica one service step — unless its throttle
        says to skip this fleet step (the straggler simulation).
        Returns whether the service did any work."""
        self._ticks += 1
        if (self._ticks - 1) % self.throttle != 0:
            return False
        with self._ctx():
            return self.svc.step()

    # ---- router-facing load signals ----------------------------------

    def load(self) -> int:
        """Assigned load: the replica's QUEUED + RUNNING queries."""
        return self.svc.load()

    def rounds_remaining(self) -> float:
        """Estimated rounds of work left in this replica (the EWMA
        export of :meth:`QueryService.rounds_remaining`)."""
        return self.svc.rounds_remaining()

    def queue_head_age(self) -> int:
        """Steps the replica's oldest pending query has waited."""
        return self.svc.queue_head_age()


def jax_default_device(device):
    """``jax.default_device(device)`` as a lazy import, so the pure
    router/trace modules never pull jax in."""
    import jax
    return jax.default_device(device)
