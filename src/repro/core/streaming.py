"""Streaming graph mutations with incremental label repair
(DESIGN.md section 10).

The paper's balancer assumes a static CSR; this module makes the CSR a
*versioned* container that absorbs batched edge updates at **fixed
array shapes**, so the jitted round functions compiled for a graph
keep serving it across arbitrarily many mutations — no recompiles, no
shape churn.  Three layers:

* **Update batches** — :class:`UpdateBatch` is a fixed-capacity
  ``int32[K]`` quadruple (op, src, dst, w); ops are insert / delete /
  reweight, padding slots are no-ops.  :func:`make_batch` builds one
  from Python tuples, bucketing K so a stream of batches reuses one
  shape.
* **Versioned application** — :func:`streaming_graph` prepares a Graph
  for updates (sentinel padded vertex, bucketed edge capacity, host
  edge map); :func:`apply_updates` replays a batch into the host edge
  map and rebuilds the CSR *at the same shapes*, bumping
  :attr:`Graph.version` so every memoized derived structure (the
  ``reverse()`` transpose, the balancer's pull enumerations) is
  invalidated atomically.  :func:`diff_batch` reports the **net**
  topology delta a batch would cause — the unit both the repair seeds
  and the serve-layer cache eviction consume.
* **Incremental repair** — :func:`stream_init` / :func:`stream_update`
  maintain a label fixpoint for a monotone app (bfs/sssp/cc) across
  updates.  Improvements (inserted edges, sssp weight decreases) are
  repaired *incrementally*: the changed edges' endpoints become a
  frontier (``frontier.seed_from_edges``) and the ordinary round loop
  resumes from the current labels (``drivers.resume_loop``) — the
  exact relax machinery of a from-scratch run, so every strategy,
  backend, execution mode and traversal direction applies unchanged.
  Degradations (a deleted or weight-increased edge that is *tight*,
  i.e. currently supports some label) fall back to a full recompute,
  because min-combine resumption can only lower labels.

Correctness contract, enforced by ``tests/test_streaming.py``: after
every update the real-vertex slice of the maintained labels is bitwise
equal to a from-scratch run on the mutated graph.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, INF, to_coo
from .frontier import next_bucket, seed_from_edges
from .balancer import BalancerConfig
from . import operators as ops
from .apps import drivers

# UpdateBatch op codes.  0 must be the padding no-op so a zeroed array
# is a valid (empty) batch.
OP_PAD = 0
OP_INSERT = 1
OP_DELETE = 2
OP_REWEIGHT = 3

_OP_NAMES = {"insert": OP_INSERT, "delete": OP_DELETE,
             "reweight": OP_REWEIGHT}

# The monotone (min-combine) applications the repair path maintains.
# bfs and cc are weight-blind (uses_weight=False): reweights never
# change their fixpoint, so the classifier ignores them outright.
STREAM_APPS = {
    "bfs": ops.BFS_HOP,
    "sssp": ops.SSSP_RELAX,
    "cc": ops.CC_MIN,
}


class UpdateBatch(NamedTuple):
    """Fixed-shape batch of edge updates: four ``int32[K]`` host
    arrays.  ``op[k]`` is one of :data:`OP_PAD` (slot unused),
    :data:`OP_INSERT`, :data:`OP_DELETE`, :data:`OP_REWEIGHT`;
    ``src``/``dst`` name the edge and ``w`` carries the new weight
    (ignored for deletes).  K is the batch *capacity* — a stream that
    sticks to one capacity hands the jitted seeding scatter one shape
    forever (DESIGN.md section 10)."""
    op: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray

    @property
    def capacity(self) -> int:
        """The fixed slot count K (live entries + padding)."""
        return int(self.op.shape[0])

    @property
    def num_updates(self) -> int:
        """How many live (non-padding) entries the batch carries."""
        return int(np.count_nonzero(self.op))


def make_batch(updates: Iterable[tuple],
               capacity: Optional[int] = None) -> UpdateBatch:
    """Build an :class:`UpdateBatch` from Python tuples.

    Each update is ``("insert", u, v, w)``, ``("delete", u, v)`` or
    ``("reweight", u, v, w)``; unweighted streams pass ``w=1``.
    ``capacity`` fixes K explicitly (a stream should pick one capacity
    and keep it — mixed capacities re-trace the seeding scatter);
    by default K is bucketed to the smallest power of two >= max(n,
    16), so nearby batch sizes share a shape.  Entries beyond ``n`` are
    :data:`OP_PAD` no-ops.
    """
    parsed = []
    for t in updates:
        kind = t[0]
        if kind not in _OP_NAMES:
            raise ValueError(f"unknown update kind {kind!r} "
                             f"(have {sorted(_OP_NAMES)})")
        if kind == "delete":
            u, v = t[1], t[2]
            w = 0
        else:
            if len(t) != 4:
                raise ValueError(f"{kind} update needs (kind, u, v, w); "
                                 f"got {t!r}")
            u, v, w = t[1], t[2], t[3]
        parsed.append((_OP_NAMES[kind], int(u), int(v), int(w)))
    n = len(parsed)
    cap = next_bucket(n, minimum=16) if capacity is None else int(capacity)
    if n > cap:
        raise ValueError(f"{n} updates exceed batch capacity {cap}")
    op = np.zeros((cap,), np.int32)
    src = np.zeros((cap,), np.int32)
    dst = np.zeros((cap,), np.int32)
    w = np.zeros((cap,), np.int32)
    for i, (o, u, v, wt) in enumerate(parsed):
        op[i], src[i], dst[i], w[i] = o, u, v, wt
    return UpdateBatch(op=op, src=src, dst=dst, w=w)


# ---------------------------------------------------------------------------
# Versioned CSR application.
# ---------------------------------------------------------------------------

def real_vertices(g: Graph) -> int:
    """The live vertex count of a (possibly streaming-padded) graph:
    vertices ``>= real_vertices(g)`` are structural padding whose
    labels carry no meaning.  Equals ``num_vertices`` for graphs never
    passed through :func:`streaming_graph`."""
    return g.__dict__.get("_v_real", g.num_vertices)


def edge_map(g: Graph) -> Dict[Tuple[int, int], int]:
    """The graph's live edge set as a host dict ``(u, v) -> w``,
    memoized per :attr:`Graph.version` (a mutation invalidates it with
    the other derived structures).  Padded edges — those leaving a
    padded source vertex — are excluded, so the dict is exactly the
    semantic edge set :func:`apply_updates` rebuilds the CSR from.
    Treat the returned dict as read-only; it IS the cache entry.
    """
    cached = g.__dict__.get("_edge_map_cache")
    if cached is not None and cached[0] == g.version:
        return cached[1]
    v_real = real_vertices(g)
    src, dst, w = to_coo(g)
    live = src < v_real                 # padded vertices have no real edges
    edges = {(int(u), int(v)): int(wt)
             for u, v, wt in zip(src[live], dst[live], w[live])}
    object.__setattr__(g, "_edge_map_cache", (g.version, edges))
    return edges


def unpadded(g: Graph) -> Graph:
    """The semantic (un-padded) graph a streaming-shaped graph
    represents: real vertices only, live edges only, no sentinel.  Use
    this to hand a mutated graph to consumers that assume exact shapes
    — the partitioner, benchmark symmetrizers — at the cost of losing
    the fixed-shape/no-recompile property (it is a fresh Graph at
    version 0)."""
    v_real = real_vertices(g)
    edges = edge_map(g)
    n = len(edges)
    src = np.fromiter((k[0] for k in edges), np.int64, count=n)
    dst = np.fromiter((k[1] for k in edges), np.int64, count=n)
    w = np.fromiter(edges.values(), np.int64, count=n)
    from .graph import from_edge_list
    return from_edge_list(src, dst, v_real, weights=w, dedup=False)


def streaming_graph(g: Graph, edge_capacity: Optional[int] = None) -> Graph:
    """Prepare a graph for :func:`apply_updates`: returns a copy padded
    to *streaming shape* — vertex count rounded up past a sentinel
    (``vp - 1``, the degree-0 target every padded edge aims at, per the
    ``pad_graph`` invariant) and edge count bucketed to a power of two
    with headroom, so later updates rebuild the CSR at these exact
    shapes and jitted round functions never recompile.

    ``edge_capacity`` fixes the edge headroom explicitly (it is
    bucketed up); the default leaves ~50% growth room.  A batch that
    overflows the capacity still applies — the CSR grows to the next
    bucket — but that one application changes shapes and re-traces, so
    size the capacity for the stream's lifetime.
    """
    v_real = g.num_vertices
    edges = edge_map(g)
    vp = -(-(v_real + 1) // 8) * 8      # >= v_real + 1, multiple of 8
    want = len(edges) if edge_capacity is None else int(edge_capacity)
    if want < len(edges):
        raise ValueError(f"edge_capacity {want} < current edge count "
                         f"{len(edges)}")
    if edge_capacity is None:
        want = len(edges) + max(64, len(edges) // 2)
    ecap = next_bucket(want, minimum=1024)
    out = _rebuild(edges, v_real, vp, ecap, version=0)
    return out


def _rebuild(edges: Dict[Tuple[int, int], int], v_real: int, vp: int,
             ecap: int, version: int) -> Graph:
    """Host-side CSR build of ``edges`` at fixed (vp, ecap) shapes.
    Padded edges target the sentinel vertex ``vp - 1`` with weight INF
    (the ``pad_graph`` invariant: weight-blind operators may relax
    them, but only the sentinel's never-read label is written)."""
    n = len(edges)
    if n > ecap:
        ecap = next_bucket(n, minimum=1024)     # documented re-trace
    src = np.fromiter((k[0] for k in edges), np.int64, count=n)
    dst = np.fromiter((k[1] for k in edges), np.int64, count=n)
    w = np.fromiter(edges.values(), np.int64, count=n)
    order = np.lexsort((dst, src))
    src, dst, w = src[order], dst[order], w[order]
    counts = np.bincount(src, minlength=vp).astype(np.int32)
    row_ptr = np.zeros(vp + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    col_idx = np.full((ecap,), vp - 1, dtype=np.int32)
    col_idx[:n] = dst
    edge_w = np.full((ecap,), INF, dtype=np.int32)
    edge_w[:n] = w
    out = Graph(row_ptr=jnp.asarray(row_ptr),
                col_idx=jnp.asarray(col_idx),
                edge_w=jnp.asarray(edge_w))
    object.__setattr__(out, "_v_real", v_real)
    object.__setattr__(out, "_version", version)
    object.__setattr__(out, "_edge_map_cache", (version, edges))
    return out


def _apply_ops(edges: Dict[Tuple[int, int], int], batch: UpdateBatch,
               v_real: int) -> Dict[Tuple[int, int], int]:
    """Replay a batch into a COPY of the edge dict, slot order.
    Semantics (deliberately closed over every input): insert keeps the
    MIN of duplicate weights (the ``from_edge_list`` dedup rule);
    delete of an absent edge is a no-op; reweight sets the weight
    exactly — including increases — but only if the edge exists."""
    out = dict(edges)
    for i in range(batch.capacity):
        o = int(batch.op[i])
        if o == OP_PAD:
            continue
        u, v, w = int(batch.src[i]), int(batch.dst[i]), int(batch.w[i])
        if not (0 <= u < v_real and 0 <= v < v_real):
            raise ValueError(f"update slot {i}: edge ({u}, {v}) out of "
                             f"range [0, {v_real})")
        if o == OP_DELETE:
            out.pop((u, v), None)
            continue
        if not 1 <= w < int(INF):
            raise ValueError(f"update slot {i}: weight {w} outside "
                             f"[1, INF)")
        if o == OP_INSERT:
            cur = out.get((u, v))
            out[(u, v)] = w if cur is None else min(cur, w)
        elif o == OP_REWEIGHT:
            if (u, v) in out:
                out[(u, v)] = w
        else:
            raise ValueError(f"update slot {i}: unknown op code {o}")
    return out


def apply_updates(g: Graph, batch: UpdateBatch,
                  in_place: bool = False) -> Graph:
    """Apply one :class:`UpdateBatch` to a streaming-shaped graph.

    The host edge map is updated and the CSR rebuilt at the graph's
    existing (V, E) shapes — col_idx/edge_w padding targets the
    sentinel vertex — so every jitted function traced for the graph is
    reused verbatim; only an edge-capacity overflow grows E (to the
    next bucket, re-tracing once).  The result's :attr:`Graph.version`
    is the input's plus one, which atomically invalidates the memoized
    ``reverse()`` transpose, the balancer's pull enumerations, and the
    edge map itself.

    ``in_place=False`` (default) returns a NEW Graph and leaves ``g``
    untouched — the serve layer relies on this to let in-flight
    queries drain against the pre-update snapshot.  ``in_place=True``
    swaps the arrays underneath ``g`` and bumps its version: every
    existing reference observes the mutation (and, via the version
    key, never a stale derived cache).

    Requires a graph produced by :func:`streaming_graph` (or a prior
    ``apply_updates``): without the sentinel vertex there is nowhere
    safe to aim edge padding.
    """
    if "_v_real" not in g.__dict__:
        raise ValueError("graph is not streaming-enabled; wrap it with "
                         "streaming_graph(g) first")
    v_real = real_vertices(g)
    edges = _apply_ops(edge_map(g), batch, v_real)
    new = _rebuild(edges, v_real, g.num_vertices, g.num_edges,
                   version=g.version + 1)
    if not in_place:
        return new
    object.__setattr__(g, "row_ptr", new.row_ptr)
    object.__setattr__(g, "col_idx", new.col_idx)
    object.__setattr__(g, "edge_w", new.edge_w)
    g.bump_version()
    object.__setattr__(g, "_edge_map_cache", (g.version, edges))
    return g


# ---------------------------------------------------------------------------
# Net topology deltas.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetDelta:
    """The NET effect a batch has on the edge set — final state vs
    pre-batch state per (u, v) pair, so in-batch churn (insert then
    delete, duplicate inserts, no-op reweights) collapses away.

    ``added``      — ``(u, v, w_new)`` edges absent before, present after;
    ``removed``    — ``(u, v, w_pre)`` edges present before, absent after;
    ``reweighted`` — ``(u, v, w_pre, w_new)`` edges present in both with
    a changed weight.
    """
    added: List[Tuple[int, int, int]]
    removed: List[Tuple[int, int, int]]
    reweighted: List[Tuple[int, int, int, int]]

    def is_empty(self) -> bool:
        """True when the batch was a semantic no-op."""
        return not (self.added or self.removed or self.reweighted)

    def sources(self) -> List[int]:
        """Sorted unique source endpoints of every changed edge — the
        serve layer's eviction probe (DESIGN.md section 10): a change
        at edge (u, v) can affect labels-from-s only if u lies in s's
        reachable region, so cache entries whose region tag misses all
        of these vertices provably survive the update."""
        vs = {u for (u, _, _) in self.added}
        vs |= {u for (u, _, _) in self.removed}
        vs |= {u for (u, _, _, _) in self.reweighted}
        return sorted(vs)


def diff_batch(g: Graph, batch: UpdateBatch) -> NetDelta:
    """Classify the net delta ``batch`` would cause on ``g`` WITHOUT
    applying it (pure).  Call before :func:`apply_updates` (the serve
    layer does) to know which cache regions to probe; the repair path
    uses the same classification to choose seeds vs fallback."""
    before = edge_map(g)
    after = _apply_ops(before, batch, real_vertices(g))
    touched = set()
    for i in range(batch.capacity):
        if int(batch.op[i]) != OP_PAD:
            touched.add((int(batch.src[i]), int(batch.dst[i])))
    added, removed, reweighted = [], [], []
    for k in sorted(touched):
        b, a = before.get(k), after.get(k)
        if b is None and a is not None:
            added.append((k[0], k[1], a))
        elif b is not None and a is None:
            removed.append((k[0], k[1], b))
        elif b is not None and a is not None and b != a:
            reweighted.append((k[0], k[1], b, a))
    return NetDelta(added=added, removed=removed, reweighted=reweighted)


# ---------------------------------------------------------------------------
# Incremental label repair.
# ---------------------------------------------------------------------------

def _tight(app: str, lab: np.ndarray, u: int, v: int, w: int) -> bool:
    """Does edge (u, v, w) currently *support* label[v]?  At a
    min-combine fixpoint every edge satisfies lab[v] <= msg(lab[u]);
    the edge is tight when equality holds — removing or worsening it
    may invalidate lab[v], so the repair must fall back to a full
    recompute (resumption can only lower labels, never raise them)."""
    lu, lv = int(lab[u]), int(lab[v])
    if app == "bfs":
        return lu < int(INF) and lu + 1 == lv
    if app == "sssp":
        return lu < int(INF) and lu + w == lv
    return lu == lv                     # cc: min-label propagation


@dataclasses.dataclass
class UpdateReport:
    """What one :func:`stream_update` did: ``rounds`` of relax work
    (0 for a semantic no-op), whether it had to ``full_recompute``
    (a tight edge was removed/worsened), how many changed edges
    ``seeds`` the incremental frontier started from, and the graph
    ``version`` the labels now correspond to."""
    rounds: int
    full_recompute: bool
    seeds: int
    version: int


@dataclasses.dataclass
class StreamState:
    """A live label fixpoint riding a mutating graph: the graph, the
    app (key into :data:`STREAM_APPS`), the current labels (full
    padded ``[V]``; the semantic slice is ``real_labels``), the query
    source (None for cc), and the balancer config / execution mode the
    repair rounds run with — identical knobs to a from-scratch driver
    run, which is what parity is asserted against."""
    g: Graph
    app: str
    labels: jax.Array
    source: Optional[int]
    cfg: BalancerConfig
    mode: str
    version: int

    @property
    def real_labels(self) -> np.ndarray:
        """Host copy of the labels over REAL vertices only — padding
        (including the sentinel) is repair scratch and is excluded
        from every parity guarantee."""
        return np.asarray(self.labels)[: real_vertices(self.g)]


def _full_compute(g: Graph, app: str, source: Optional[int],
                  cfg: BalancerConfig, mode: str):
    """From-scratch driver run — both ``stream_init`` and the delete
    fallback go through here, so incremental and fallback labels come
    from the same machinery."""
    if app == "bfs":
        return drivers.bfs(g, source, cfg, mode=mode)
    if app == "sssp":
        return drivers.sssp(g, source, cfg, mode=mode)
    if app == "cc":
        return drivers.cc(g, cfg, mode=mode)
    raise ValueError(f"unknown streaming app {app!r} "
                     f"(have {sorted(STREAM_APPS)})")


def stream_init(g: Graph, app: str, source: Optional[int] = None,
                cfg: BalancerConfig = BalancerConfig(),
                mode: str = "host") -> StreamState:
    """Start maintaining ``app`` labels over a mutating graph: wraps
    ``g`` to streaming shape if needed, runs the from-scratch driver
    once, and returns the :class:`StreamState` that
    :func:`stream_update` advances per batch.  ``source`` is required
    for bfs/sssp and must be omitted for cc."""
    if app not in STREAM_APPS:
        raise ValueError(f"unknown streaming app {app!r} "
                         f"(have {sorted(STREAM_APPS)})")
    if (source is None) != (app == "cc"):
        raise ValueError("bfs/sssp require a source; cc forbids one")
    if "_v_real" not in g.__dict__:
        g = streaming_graph(g)
    res = _full_compute(g, app, source, cfg, mode)
    return StreamState(g=g, app=app, labels=res.labels, source=source,
                       cfg=cfg, mode=mode, version=g.version)


def stream_update(state: StreamState, batch: UpdateBatch,
                  in_place: bool = False,
                  max_rounds: int = 10_000) -> UpdateReport:
    """Apply a batch to the state's graph and repair its labels to the
    new fixpoint.  Mutates ``state`` (graph, labels, version) and
    returns an :class:`UpdateReport`.

    Classification per the net delta (DESIGN.md section 10):

    * any removed edge — or, for sssp, weight-increased edge — that is
      *tight* under the current labels forces a **full recompute**;
    * otherwise the added edges (plus sssp weight decreases) seed a
      frontier via ``seed_from_edges`` and the ordinary round loop
      resumes from the current labels (**incremental repair**);
    * a semantic no-op batch costs zero rounds.

    bfs and cc are weight-blind, so reweights never affect them.
    ``in_place`` is forwarded to :func:`apply_updates` (the serve
    layer keeps it False to preserve pre-update snapshots).
    """
    delta = diff_batch(state.g, batch)
    g2 = apply_updates(state.g, batch, in_place=in_place)
    app = state.app
    lab = np.asarray(state.labels)

    full = any(_tight(app, lab, u, v, w) for (u, v, w) in delta.removed)
    seeds = [(u, v) for (u, v, _) in delta.added]
    if app == "sssp" and not full:
        for (u, v, wp, wn) in delta.reweighted:
            if wn > wp and _tight("sssp", lab, u, v, wp):
                full = True
                break
            if wn < wp:
                seeds.append((u, v))

    if full:
        res = _full_compute(g2, app, state.source, state.cfg, state.mode)
        labels, rounds = res.labels, res.rounds
    elif seeds:
        k = batch.capacity              # one shape per stream capacity
        s = np.zeros((k,), np.int32)
        d = np.zeros((k,), np.int32)
        m = np.zeros((k,), bool)
        for i, (u, v) in enumerate(seeds):
            s[i], d[i], m[i] = u, v, True
        frontier = seed_from_edges(jnp.asarray(s), jnp.asarray(d),
                                   jnp.asarray(m), g2.num_vertices)
        op = STREAM_APPS[app]
        res = drivers.resume_loop(g2, state.labels, frontier, state.cfg,
                                  op, max_rounds=max_rounds,
                                  mode=state.mode)
        labels, rounds = res.labels, res.rounds
    else:
        labels, rounds = state.labels, 0

    state.g = g2
    state.labels = labels
    state.version = g2.version
    return UpdateReport(rounds=rounds, full_recompute=full,
                        seeds=len(seeds), version=g2.version)
