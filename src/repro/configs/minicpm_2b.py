"""minicpm-2b [dense]: llama-like, trained with the WSD schedule.
[arXiv:2404.06395; hf]  40L d_model=2304 36H (kv=36) d_ff=5760
vocab=122753."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, tie_embeddings=True,
)

# the arch's training recipe: WSD (see repro.optim.schedules.wsd_schedule)
LR_SCHEDULE = "wsd"

SMOKE = CONFIG.scaled(num_layers=3, d_model=48, num_heads=4,
                      num_kv_heads=4, d_ff=96, vocab_size=256)
