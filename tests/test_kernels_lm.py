"""LM-side Pallas kernels vs ref.py oracles (shape/dtype sweeps)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_dispatch import positions_in_expert_kernel
from repro.kernels import ref


@pytest.mark.parametrize("s", [128, 256, 512])
@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(s, h, hkv, dtype):
    key = jax.random.PRNGKey(s + h)
    b, hd = 2, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("block_q,block_k", [(128, 128), (128, 256),
                                             (256, 128)])
def test_flash_attention_block_sweep(block_q, block_k):
    key = jax.random.PRNGKey(0)
    b, s, h, hd = 1, 512, 2, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=block_q,
                          block_k=block_k)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_non_causal():
    key = jax.random.PRNGKey(3)
    b, s, h, hd = 2, 256, 2, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=False)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,e", [(64, 8), (1000, 64), (4096, 16)])
def test_positions_in_expert_matches_ref(n, e):
    key = jax.random.PRNGKey(n)
    flat = jax.random.randint(key, (n,), 0, e, jnp.int32)
    got = positions_in_expert_kernel(flat, e, tile=256)
    want = ref.positions_in_expert_ref(flat, e)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=300))
def test_positions_property(assignments):
    """Property: within each expert, positions are 0..count-1 in
    arrival order."""
    flat = jnp.asarray(np.asarray(assignments, np.int32))
    pos = np.asarray(positions_in_expert_kernel(flat, 8, tile=64))
    a = np.asarray(assignments)
    for e in range(8):
        got = pos[a == e]
        np.testing.assert_array_equal(got, np.arange(len(got)))
