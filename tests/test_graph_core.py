"""Graph container + generator invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph as G


def test_csr_from_edge_list_roundtrip():
    src = np.array([0, 0, 1, 3, 3, 3])
    dst = np.array([1, 2, 2, 0, 1, 2])
    g = G.from_edge_list(src, dst, 4)
    assert g.num_vertices == 4
    assert g.num_edges == 6
    np.testing.assert_array_equal(np.asarray(g.row_ptr), [0, 2, 3, 3, 6])
    np.testing.assert_array_equal(np.asarray(g.out_degrees()), [2, 1, 0, 3])


def test_from_edge_list_dedup():
    g = G.from_edge_list(np.array([0, 0, 0]), np.array([1, 1, 2]), 3)
    assert g.num_edges == 2


def test_rmat_power_law():
    g = G.rmat(10, 8, seed=0)
    assert g.num_vertices == 1024
    deg = np.asarray(g.out_degrees())
    # power-law: max degree far above mean
    assert deg.max() > 10 * deg.mean()
    assert int(deg.sum()) == g.num_edges


def test_road_grid_flat_degree():
    g = G.road_grid(16)
    deg = np.asarray(g.out_degrees())
    assert deg.max() <= 4
    assert g.num_vertices == 256


def test_uniform_balanced():
    g = G.uniform_random(1024, 8, seed=0)
    deg = np.asarray(g.out_degrees())
    assert deg.max() < 8 * deg.mean()


def test_reverse_graph_preserves_edges():
    g = G.rmat(8, 4, seed=1)
    rg = G.reverse_graph(g)
    assert rg.num_edges == g.num_edges
    # reversing twice restores the out-degree multiset
    rrg = G.reverse_graph(rg)
    np.testing.assert_array_equal(
        np.sort(np.asarray(rrg.out_degrees())),
        np.sort(np.asarray(g.out_degrees())))


def test_pad_graph_alignment_and_semantics():
    g = G.rmat(7, 3, seed=2)
    gp = G.pad_graph(g, v_multiple=8, e_multiple=1024)
    assert gp.num_vertices % 8 == 0
    assert gp.num_edges % 1024 == 0
    # padded vertices have degree 0
    deg = np.asarray(gp.out_degrees())
    assert (deg[g.num_vertices:] == 0).all()
    # real structure unchanged
    np.testing.assert_array_equal(np.asarray(gp.row_ptr[: g.num_vertices + 1]),
                                  np.asarray(g.row_ptr))


def test_highest_out_degree_vertex():
    g = G.rmat(8, 8, seed=0)
    v = G.highest_out_degree_vertex(g)
    deg = np.asarray(g.out_degrees())
    assert deg[v] == deg.max()
