"""Pallas TPU kernel: position-in-expert computation for MoE dispatch.

This is the ALB prefix machinery applied to token routing (DESIGN.md
section 5): given the flat expert assignment of T*K token-slots, each
slot needs its arrival rank within its expert — exactly the exclusive
prefix sum the graph LB executor builds over vertex degrees.

TPU mapping: the grid walks token tiles SEQUENTIALLY (TPU grid steps
execute in order), carrying per-expert running counters in a VMEM
accumulator — a tile-parallel scan with an O(E) carry, instead of the
O(T*K x E) one-hot cumsum matrix the pure-jnp oracle materializes
(moe._positions_in_expert).  Output: pos[i] = #earlier slots routed to
the same expert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(eid_ref, pos_ref, counts_ref, *, num_experts, tile):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    eid = eid_ref[0, :]                          # [tile]
    onehot = (eid[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (tile, num_experts), 1))
    onehot = onehot.astype(jnp.int32)
    # rank within tile (exclusive) + carried per-expert base
    excl = jnp.cumsum(onehot, axis=0) - onehot   # [tile, E]
    base = counts_ref[0, :]                      # [E]
    pos = jnp.sum((excl + base[None, :]) * onehot, axis=1)
    pos_ref[0, :] = pos
    counts_ref[0, :] = base + jnp.sum(onehot, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("num_experts", "tile", "interpret"))
def positions_in_expert_kernel(flat_expert, num_experts: int,
                               tile: int = 1024, interpret: bool = True):
    """flat_expert: [N] int32 -> pos: [N] int32 (arrival rank within
    expert)."""
    n = flat_expert.shape[0]
    np_ = -(-n // tile) * tile
    pad = np_ - n
    e = flat_expert
    if pad:
        e = jnp.pad(e, (0, pad), constant_values=num_experts + 1)
    grid = np_ // tile
    kern = functools.partial(_kernel, num_experts=num_experts, tile=tile)
    pos, _ = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, tile), lambda i: (0, i)),
                   pl.BlockSpec((1, num_experts), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, np_), jnp.int32),
                   jax.ShapeDtypeStruct((1, num_experts), jnp.int32)],
        interpret=interpret,
    )(e[None, :])
    return pos[0, :n]
