"""Replayable routing trace (DESIGN.md section 13).

Every executed routing decision — initial placements and hedge
launches alike — appends one :class:`TraceRow` holding the FULL
:class:`~repro.serve.fleet.router.DecisionInputs` plus the decision
the live router took.  Because :func:`repro.serve.fleet.router.decide`
is a pure function of those inputs, :func:`replay` can re-derive every
decision offline and compare it bitwise against the recorded output:
zero divergences is the fleet's determinism witness (the analog of
the engine's ``admission_log``), and any corruption of a row — or any
drift between the deployed ``decide`` and the one that produced the
trace — is reported with its sequence number.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from .router import DecisionInputs, decide


@dataclasses.dataclass(frozen=True)
class TraceRow:
    """One executed routing decision: its pure inputs and the output
    the live router chose."""
    inputs: DecisionInputs
    choice: int                     # replica id the query went to
    reason: str                     # affinity | spill | p2c | hedge


@dataclasses.dataclass(frozen=True)
class Divergence:
    """One replay mismatch: the recorded decision vs what ``decide``
    derives from the recorded inputs."""
    seq: int
    recorded: Tuple[int, str]
    derived: Tuple[int, str]


class RoutingTrace:
    """Append-only log of executed routing decisions."""

    def __init__(self) -> None:
        self.rows: List[TraceRow] = []

    def append(self, inputs: DecisionInputs, choice: int,
               reason: str) -> None:
        """Record one executed decision (inputs + output)."""
        self.rows.append(TraceRow(inputs=inputs, choice=choice,
                                  reason=reason))

    def __len__(self) -> int:
        return len(self.rows)


def replay(rows: List[TraceRow]) -> List[Divergence]:
    """Re-derive every recorded decision from its recorded inputs and
    return the divergences (empty == the trace is exactly
    reproducible).  This is the offline half of the routing-replay
    gate: it never touches a fleet, only the pure ``decide``."""
    out: List[Divergence] = []
    for row in rows:
        derived = decide(row.inputs)
        if derived != (row.choice, row.reason):
            out.append(Divergence(seq=row.inputs.seq,
                                  recorded=(row.choice, row.reason),
                                  derived=derived))
    return out


def ceiling_violations(rows: List[TraceRow]) -> List[int]:
    """Sequence numbers of decisions whose chosen replica exceeded the
    bounded-load ceiling ``ceil(c * (total + 1) / n)`` AFTER admission
    — the structural half of the bounded-load gate (must be empty)."""
    from .router import load_ceiling
    bad = []
    for row in rows:
        ceil_ = load_ceiling(row.inputs.loads,
                             row.inputs.capacity_factor)
        if row.inputs.loads[row.choice] + 1 > ceil_:
            bad.append(row.inputs.seq)
    return bad
