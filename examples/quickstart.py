"""Quickstart: the paper's scenario end-to-end in 40 lines.

Builds a power-law graph, runs SSSP under every load-balancing
strategy, and shows the ALB inspector firing only where imbalance
exists.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import graph as G
from repro.core.balancer import BalancerConfig
from repro.core.apps import sssp

# power-law graph (rmat): a few vertices own most edges
g = G.rmat(scale=12, edge_factor=16, seed=0)
src = G.highest_out_degree_vertex(g)
print(f"graph: |V|={g.num_vertices} |E|={g.num_edges} "
      f"max_out_degree={g.max_out_degree()}")

results = {}
for strategy in ["vertex", "twc", "edge_lb", "alb"]:
    cfg = BalancerConfig(strategy=strategy, threshold=256)
    r = sssp(g, src, cfg, collect_stats=True)
    results[strategy] = r
    fired = sum(st.lb_invoked for st in r.stats)
    print(f"{strategy:8s}: {r.seconds * 1e3:8.1f} ms  "
          f"rounds={r.rounds}  LB-kernel-fired={fired}/{len(r.stats)}")

# all strategies agree on the fixpoint
base = np.asarray(results["twc"].labels)
for s, r in results.items():
    assert np.array_equal(np.asarray(r.labels), base), s
print("all strategies computed identical shortest paths ✓")

# flat graph: the inspector never fires (paper: 'negligible overhead')
road = G.road_grid(48, seed=0)
r = sssp(road, 0, BalancerConfig(strategy="alb", threshold=256),
         collect_stats=True)
print(f"road graph: LB fired "
      f"{sum(st.lb_invoked for st in r.stats)}/{len(r.stats)} rounds "
      f"(adaptive: stays out of the way)")
