"""Query objects and the submit/poll queue of the serving layer.

A :class:`Query` is one point lookup — "run ``app`` from ``source`` on
the graph registered as ``graph_id``" — moving through the lifecycle

    QUEUED -> RUNNING -> DONE          (or QUEUED -> DONE on cache hit)
         ^       |
         +-------+   (preempted: back of the queue, slot state saved)

with a third terminal state, CANCELLED, reached from QUEUED or RUNNING
via :meth:`QueryService.cancel` — the fleet layer (DESIGN.md
section 13) cancels the losing finisher of a hedged query.

:class:`QueryQueue` is the bookkeeping half of the service: it assigns
monotonically increasing query ids (the FIFO admission key the
scheduler orders by, so admission is deterministic — DESIGN.md
section 8), holds the pending deque, and answers ``poll``.  It never
touches the device; slot state lives in ``repro.serve.engine``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import numpy as np

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"


@dataclasses.dataclass
class Query:
    """One submitted point query and its full service-side record."""
    qid: int
    graph_id: str
    app: str                       # key into apps.drivers.QUERY_APPS
    source: int
    status: str = QUEUED
    result: Optional[np.ndarray] = None   # final labels[V] (host copy)
    from_cache: bool = False
    submit_step: int = 0           # service step at submission
    done_step: Optional[int] = None
    slot: Optional[int] = None     # occupied slot while RUNNING
    slot_rounds: int = 0           # consecutive rounds in current slot
    preemptions: int = 0
    # preemption snapshot: (labels_row[V], frontier_row[V]) host copies
    saved_state: Optional[tuple] = None
    # graph version the query is bound to: stamped at submission,
    # rebound at admission if the graph mutated while it queued
    # (DESIGN.md section 10) — results are cached only when this
    # matches the graph's current version
    version: int = 0
    # single-flight registration key (includes the version), popped by
    # the engine when the query completes or is rebound
    inflight_key: Optional[tuple] = None

    @property
    def rounds_in_system(self) -> Optional[int]:
        """Service steps from submission to completion (queue wait +
        slot residency; 0 for a cache hit served at submission)."""
        if self.done_step is None:
            return None
        return self.done_step - self.submit_step


class QueryQueue:
    """Submit/poll bookkeeping: id assignment, the pending FIFO, and
    the qid -> :class:`Query` table."""

    def __init__(self) -> None:
        self._next_qid = 0
        self._queries: dict[int, Query] = {}
        self._pending: deque[int] = deque()

    def submit(self, graph_id: str, app: str, source: int,
               step: int, enqueue: bool = True) -> Query:
        """Create a QUEUED query and (unless ``enqueue=False`` — the
        cache-hit path, answered at submission) append it to the
        pending FIFO."""
        q = Query(qid=self._next_qid, graph_id=graph_id, app=app,
                  source=int(source), submit_step=step)
        self._next_qid += 1
        self._queries[q.qid] = q
        if enqueue:
            self._pending.append(q.qid)
        return q

    def poll(self, qid: int) -> Query:
        """Look up a query's current record (status, result, timings)."""
        return self._queries[qid]

    def requeue(self, q: Query) -> None:
        """Preemption path: a RUNNING query goes to the BACK of the
        FIFO (round-robin fairness) with its slot state saved."""
        q.status = QUEUED
        q.slot = None
        q.slot_rounds = 0
        self._pending.append(q.qid)

    def next_pending(self, graph_id: str, app: str) -> Optional[Query]:
        """Pop the earliest pending query of the ``(graph_id, app)``
        slot bank (FIFO by qid); None when that bank has no queued
        work.  Banks are per (graph, app) because a balancer round
        applies ONE operator to the whole batch."""
        for i, qid in enumerate(self._pending):
            q = self._queries[qid]
            if q.graph_id == graph_id and q.app == app:
                del self._pending[i]
                return q
        return None

    def remove_pending(self, qid: int) -> None:
        """Withdraw a QUEUED query from the pending FIFO (the
        cancellation path); raises ``ValueError`` when the qid is not
        pending — e.g. a single-flight follower, which was never
        enqueued."""
        self._pending.remove(qid)

    def enqueue_existing(self, q: Query) -> None:
        """Re-enqueue an already-registered query at the back of the
        FIFO: the promotion path for a single-flight follower whose
        primary was cancelled (it must now be computed for real)."""
        q.status = QUEUED
        self._pending.append(q.qid)

    def head_submit_step(self) -> Optional[int]:
        """Submission step of the OLDEST pending query (the queue-head
        age numerator of the fleet router's tail-risk score, DESIGN.md
        section 13); None when nothing is pending."""
        return min((self._queries[qid].submit_step
                    for qid in self._pending), default=None)

    def active_count(self) -> int:
        """Queries currently QUEUED or RUNNING (the replica's assigned
        load as the fleet router sees it)."""
        return sum(q.status in (QUEUED, RUNNING)
                   for q in self._queries.values())

    def pending_count(self, graph_id: str, app: str) -> int:
        """How many queries are queued for the ``(graph_id, app)``
        bank."""
        return sum(1 for qid in self._pending
                   if self._queries[qid].graph_id == graph_id
                   and self._queries[qid].app == app)

    def banks_with_pending(self) -> list:
        """``(graph_id, app)`` bank keys with queued work, in
        first-submission order."""
        seen: dict[tuple, None] = {}
        for qid in self._pending:
            q = self._queries[qid]
            seen.setdefault((q.graph_id, q.app))
        return list(seen)

    def in_flight(self, graph_id: str) -> bool:
        """True while any query for ``graph_id`` is QUEUED/RUNNING."""
        return any(q.graph_id == graph_id
                   and q.status in (QUEUED, RUNNING)
                   for q in self._queries.values())

    def __len__(self) -> int:
        return len(self._pending)
