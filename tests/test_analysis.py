"""Fixture-corpus tests for the repro.analysis invariant linter.

For each rule: at least one minimal snippet that must be flagged and
one near-miss that must not be; plus pragma-suppression, baseline
round-trip, CLI exit-code, and seeded-regression tests (the host-sync
pass must catch a reintroduced ``bool(jnp.any(frontier))`` in a real
driver loop).  The linter is stdlib-only, so none of this touches
jax.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (analyze_paths, analyze_source, all_rules,
                            apply_baseline, load_baseline,
                            protected_violations, render_baseline,
                            rule_ids)

REPO = Path(__file__).resolve().parent.parent

CORE = "src/repro/core/somefile.py"
SERVE = "src/repro/serve/somefile.py"


def lint(source, path=CORE, relaxed=False):
    src = textwrap.dedent(source)
    return analyze_source(src, path, relaxed=relaxed)


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# registry / framework

def test_registry_has_the_five_passes_plus_pragma_hygiene():
    ids = rule_ids()
    for required in ("host-sync", "jit-purity", "static-argnames",
                     "publish-freeze", "scatter-determinism",
                     "dtype-narrowing", "bad-pragma"):
        assert required in ids
    assert len(all_rules()) >= 7


def test_findings_format_is_file_line_rule_message():
    (f,) = lint("""
        import jax.numpy as jnp
        def probe(frontier):
            return bool(jnp.any(frontier))
    """)
    assert f.format() == (
        f"{CORE}:4 host-sync blocking host sync: bool() on a jnp "
        f"expression — register it with _note_host_transfer() on an "
        f"adjacent line, or pragma an intentional one-time transfer")


def test_syntax_error_is_a_parse_error_finding_not_a_crash():
    (f,) = lint("def broken(:\n")
    assert f.rule == "parse-error"


# ---------------------------------------------------------------------------
# host-sync

def test_host_sync_flags_bool_of_jnp_any():
    findings = lint("""
        import jax.numpy as jnp
        def loop(frontier):
            while bool(jnp.any(frontier)):
                frontier = step(frontier)
    """)
    assert rules_of(findings) == ["host-sync"]


def test_host_sync_flags_tainted_local_and_item_and_device_get():
    findings = lint("""
        import jax, jax.numpy as jnp
        def f(frontier):
            total = jnp.sum(frontier)
            a = int(total)
            b = total.item()
            c = jax.device_get(frontier)
            return a, b, c
    """)
    assert [f.rule for f in findings] == ["host-sync"] * 3


def test_host_sync_near_miss_numpy_and_call_results_not_flagged():
    # np.any over host data, int() of a plain attribute, and values
    # returned by user functions (the round primitives hand back
    # host-side actives) must NOT be flagged
    findings = lint("""
        import numpy as np
        def loop(g, frontier, cfg):
            new, st, active = _round(g, frontier, cfg)
            if not bool(np.any(active)):
                return new
            n = int(st.frontier_size)
            return new
    """)
    assert findings == []


def test_host_sync_allows_noted_adjacent_statement():
    findings = lint("""
        import jax.numpy as jnp
        def probe(frontier):
            _note_host_transfer()
            return bool(jnp.any(frontier))
    """)
    assert findings == []


def test_host_sync_out_of_scope_paths_are_ignored():
    bad = """
        import jax.numpy as jnp
        def probe(frontier):
            return bool(jnp.any(frontier))
    """
    assert lint(bad, path="src/repro/models/layer.py") == []
    assert lint(bad, path=CORE) != []


def test_host_sync_seeded_regression_in_real_driver_loop():
    # reintroduce the exact bug class PR 4 removed: a per-round
    # blocking bool(jnp.any(frontier)) inside the host driver loop
    drivers = REPO / "src/repro/core/apps/drivers.py"
    src = drivers.read_text()
    assert "while rounds < max_rounds:" in src
    seeded = src.replace(
        "while rounds < max_rounds:",
        "while rounds < max_rounds and bool(jnp.any(frontier)):",
        1)
    rel = os.path.relpath(drivers, Path.cwd()) \
        if str(drivers).startswith(str(Path.cwd())) \
        else "src/repro/core/apps/drivers.py"
    clean = analyze_source(src, rel)
    assert clean == [], [f.format() for f in clean]
    flagged = analyze_source(seeded, rel)
    assert any(f.rule == "host-sync" for f in flagged)


# ---------------------------------------------------------------------------
# jit-purity

def test_jit_purity_flags_if_on_traced_param():
    findings = lint("""
        import jax
        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert rules_of(findings) == ["jit-purity"]


def test_jit_purity_flags_partial_application_form():
    # name = partial(jax.jit, static_argnames=...)(impl) must resolve
    findings = lint("""
        import jax
        from functools import partial
        def _impl(x, cfg):
            while x.sum() > 0:
                x = x - 1
            return x
        run = partial(jax.jit, static_argnames=("cfg",))(_impl)
    """)
    assert rules_of(findings) == ["jit-purity"]


def test_jit_purity_flags_print_nondeterminism_and_global():
    findings = lint("""
        import jax, time
        _CACHE = {}
        @jax.jit
        def f(x):
            print(x)
            t = time.time()
            _CACHE[0] = x
            return x + t
    """)
    assert sorted(f.message.split()[0] for f in findings) == [
        "mutation", "nondeterministic", "print()"]


def test_jit_purity_near_misses_static_branches():
    # static args, .ndim/.shape metadata, `is None`, and len() are
    # all trace-safe — and non-jitted functions are out of scope
    findings = lint("""
        import jax, jax.numpy as jnp
        from functools import partial
        @partial(jax.jit, static_argnames=("cfg",))
        def f(x, cfg, acc):
            if cfg.direction == "push":
                x = x + 1
            if x.ndim == 2:
                x = x[0]
            if acc is None:
                acc = jnp.zeros_like(x)
            outs = (x, acc)
            return outs[0] if len(outs) == 1 else outs
        def host_loop(frontier):
            if frontier.any():
                return 1
            return 0
    """)
    assert findings == []


def test_jit_purity_covers_pallas_partial_kernels():
    findings = lint("""
        import functools
        import jax.experimental.pallas as pl
        def _kernel(x_ref, o_ref, *, tile):
            if x_ref[0] > 0:
                o_ref[0] = x_ref[0]
        def launch(x, tile):
            kern = functools.partial(_kernel, tile=tile)
            return pl.pallas_call(kern, grid=(1,))(x)
    """, path="src/repro/kernels/somekernel.py")
    assert rules_of(findings) == ["jit-purity"]


# ---------------------------------------------------------------------------
# static-argnames

def test_static_argnames_typo_is_flagged():
    findings = lint("""
        import jax
        from functools import partial
        def _impl(x, width, op):
            return x
        run = partial(jax.jit, static_argnames=("width", "opp"))(_impl)
    """)
    assert rules_of(findings) == ["static-argnames"]
    assert "'opp'" in findings[0].message


def test_static_argnames_matching_params_pass():
    findings = lint("""
        import jax
        from functools import partial
        @partial(jax.jit, static_argnames=("width", "op"))
        def f(x, width, op):
            return x
        def _impl(y, cfg):
            return y
        g = jax.jit(_impl, static_argnames="cfg")
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# publish-freeze

def test_publish_freeze_flags_unfrozen_result_and_cache_entry():
    findings = lint("""
        import numpy as np
        class Engine:
            def finish(self, q, labels):
                q.result = np.asarray(labels)
            def put(self, k, labels):
                self._entries[k] = labels
    """, path=SERVE)
    assert [f.rule for f in findings] == ["publish-freeze"] * 2


def test_publish_freeze_near_miss_frozen_values_pass():
    findings = lint("""
        import numpy as np
        from .publish import freeze
        class Engine:
            def finish(self, q, labels):
                labels = freeze(labels)
                q.result = labels
            def put(self, k, labels, region):
                labels.setflags(write=False)
                self._entries[k] = (labels, freeze(region))
            def reset(self, q):
                q.result = None
    """, path=SERVE)
    assert findings == []


def test_publish_freeze_only_applies_to_serve():
    bad = """
        def f(q, labels):
            q.result = labels
    """
    assert lint(bad, path=SERVE) != []
    assert lint(bad, path=CORE) == []


# ---------------------------------------------------------------------------
# scatter-determinism

def test_scatter_unregistered_combine_flagged_in_executor():
    # a path with no operators.py on disk -> default registry
    # {min,max}: .add must be flagged — proving the flag/no-flag
    # decision really comes from the operators.py registry (the repo's
    # own tree, which registers "add", passes the same snippet)
    findings = lint("""
        import jax.numpy as jnp
        def apply(labels, idx, vals):
            return labels.at[idx].add(vals)
    """, path="no/such/tree/core/balancer.py")
    assert rules_of(findings) == ["scatter-determinism"]


def test_scatter_registered_combine_passes_via_operators_registry(
        tmp_path):
    (tmp_path / "operators.py").write_text(
        'COMMUTATIVE_COMBINES = frozenset({"min", "max", "add"})\n')
    bal = tmp_path / "balancer.py"
    bal.write_text(textwrap.dedent("""
        import jax.numpy as jnp
        def apply(labels, idx, vals):
            a = labels.at[idx].add(vals)
            b = labels.at[idx].min(vals)
            return a, b
    """))
    assert analyze_paths([str(bal)]) == []


def test_scatter_set_is_flagged_and_real_registry_covers_tree():
    findings = lint("""
        def apply(labels, idx, vals):
            return labels.at[idx].set(vals)
    """, path="src/repro/kernels/somekernel.py")
    assert rules_of(findings) == ["scatter-determinism"]
    # and the real operators.py registers exactly the order-free set
    sys.path.insert(0, str(REPO / "src"))
    from repro.core import operators  # noqa: deferred-jax import
    assert operators.COMMUTATIVE_COMBINES == {"min", "max", "add"}


def test_scatter_out_of_executor_scope_ignored():
    assert lint("""
        def apply(labels, idx, vals):
            return labels.at[idx].set(vals)
    """, path="src/repro/core/frontier.py") == []


# ---------------------------------------------------------------------------
# dtype-narrowing

def test_narrow_astype_flagged_without_declaration():
    # no operators.py reachable -> nothing is declared safe
    findings = lint("""
        import jax.numpy as jnp
        def pack(labels):
            return labels.astype(jnp.uint8)
    """, path="no/such/tree/core/wire.py")
    assert rules_of(findings) == ["dtype-narrowing"]
    # string-constant dtype spelling is caught too
    findings = lint("""
        def pack(labels):
            return labels.astype("int16")
    """, path="no/such/tree/core/wire.py")
    assert rules_of(findings) == ["dtype-narrowing"]


def test_declared_narrowing_passes_via_operators_registry(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    (core / "operators.py").write_text(textwrap.dedent("""
        Operator("bfs", wire_narrow=("uint16", "int8"))
    """))
    mod = core / "wire.py"
    mod.write_text(textwrap.dedent("""
        import jax.numpy as jnp
        def pack(labels):
            ok = labels.astype(jnp.uint16)      # declared
            also = labels.astype(jnp.int8)      # declared
            return labels.astype(jnp.uint8)     # NOT declared
    """))
    findings = analyze_paths([str(mod)])
    assert rules_of(findings) == ["dtype-narrowing"]
    assert len(findings) == 1
    assert "uint8" in findings[0].message


def test_narrow_astype_out_of_core_scope_ignored():
    # the optimizer's int8 gradient quantization is not a label path
    assert lint("""
        import jax.numpy as jnp
        def quantize(g):
            return g.astype(jnp.int8)
    """, path="src/repro/optim/grad_compress.py") == []


def test_dynamic_astype_not_flagged():
    # dtype chosen at runtime (the codec layer's own dispatch) is not
    # statically resolvable and must not be flagged
    assert lint("""
        import jax.numpy as jnp
        def pack(labels, ndt):
            a = labels.astype(ndt)
            return labels.astype(jnp.int32)    # widening is fine
    """, path="no/such/tree/core/wire.py") == []


def test_narrow_astype_pragma_suppresses():
    assert lint("""
        import jax.numpy as jnp
        def pack(labels):
            return labels.astype(jnp.uint8)  # repro: allow[dtype-narrowing] -- scratch buffer, not a label path
    """, path="no/such/tree/core/wire.py") == []


def test_real_operators_declare_the_wire_narrowings():
    # the live declarations the rule (and the quantize codec) key on
    sys.path.insert(0, str(REPO / "src"))
    from repro.analysis.rules.dtype_narrowing import _parse_declarations
    declared = _parse_declarations(
        (REPO / "src/repro/core/operators.py").read_text())
    assert declared == {"uint16", "int8"}


# ---------------------------------------------------------------------------
# pragmas

def test_pragma_suppresses_named_rule_on_its_line():
    findings = lint("""
        import jax.numpy as jnp
        def seed(frontier):
            return int(jnp.sum(frontier))  # repro: allow[host-sync] -- one-time seed
    """)
    assert findings == []


def test_pragma_without_justification_is_bad_pragma():
    findings = lint("""
        import jax.numpy as jnp
        def seed(frontier):
            return int(jnp.sum(frontier))  # repro: allow[host-sync]
    """)
    assert rules_of(findings) == ["bad-pragma", "host-sync"]


def test_pragma_with_unknown_rule_is_bad_pragma():
    findings = lint("""
        def f():
            return 1  # repro: allow[no-such-rule] -- because
    """)
    assert rules_of(findings) == ["bad-pragma"]


def test_pragma_shaped_text_in_docstrings_is_ignored():
    findings = lint('''
        def f():
            """Suppress with `# repro: allow[<rule>] -- why`."""
            return 1
    ''')
    assert findings == []


# ---------------------------------------------------------------------------
# baseline

def test_baseline_round_trip(tmp_path):
    bad = tmp_path / "models" / "legacy.py"
    bad.parent.mkdir()
    bad.write_text(textwrap.dedent("""
        import jax
        @jax.jit
        def f(x):
            print(x)
            return x
    """))
    findings = analyze_paths([str(bad)])
    assert rules_of(findings) == ["jit-purity"]
    bl_file = tmp_path / "baseline.txt"
    bl_file.write_text(render_baseline(findings))
    baseline = load_baseline(bl_file)
    kept, matched, stale = apply_baseline(findings, baseline)
    assert kept == [] and matched == len(findings) and stale == []
    # a NEW finding in the same file is not grandfathered
    more = findings + [findings[0].__class__(
        path=findings[0].path, line=99, rule="jit-purity",
        message="something new")]
    kept2, _, _ = apply_baseline(more, baseline)
    assert len(kept2) == 1


def test_baseline_rejects_protected_core_and_serve_paths():
    from collections import Counter
    bl = Counter({("src/repro/core/balancer.py", "host-sync",
                   "grandfathered"): 1,
                  ("src/repro/models/x.py", "jit-purity", "ok"): 1})
    bad = protected_violations(bl)
    assert len(bad) == 1 and "balancer.py" in bad[0]


def test_committed_baseline_is_empty_for_core_and_serve():
    bl = load_baseline(REPO / "analysis-baseline.txt")
    assert protected_violations(bl) == []
    # stronger: the committed baseline is entirely empty
    assert sum(bl.values()) == 0


# ---------------------------------------------------------------------------
# CLI

def run_cli(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd or REPO, env=env)


def test_cli_clean_tree_exits_zero():
    p = run_cli("--check", "src/", "benchmarks/")
    assert p.returncode == 0, p.stdout + p.stderr
    assert "OK: 0 findings" in p.stdout


def test_cli_findings_exit_one_with_expected_format(tmp_path):
    f = tmp_path / "src" / "repro" / "core" / "bad.py"
    f.parent.mkdir(parents=True)
    f.write_text("import jax.numpy as jnp\n"
                 "def probe(fr):\n"
                 "    return bool(jnp.any(fr))\n")
    p = run_cli("--check", "--no-baseline", "src", cwd=tmp_path)
    assert p.returncode == 1
    assert "src/repro/core/bad.py:3 host-sync" in p.stdout


def test_cli_bad_path_exits_two():
    p = run_cli("--check", "no/such/dir")
    assert p.returncode == 2
    assert "no such file" in p.stderr


def test_cli_no_paths_exits_two():
    p = run_cli("--check")
    assert p.returncode == 2


def test_cli_help_lists_every_rule():
    p = run_cli("--help")
    assert p.returncode == 0
    for rid in rule_ids():
        assert rid in p.stdout


def test_cli_relaxed_profile_drops_host_sync(tmp_path):
    f = tmp_path / "tests" / "test_x.py"
    f.parent.mkdir()
    f.write_text("import jax.numpy as jnp\n"
                 "def check(fr):\n"
                 "    assert bool(jnp.any(fr))\n")
    strict = run_cli("--check", "--no-baseline", "tests", cwd=tmp_path)
    relaxed = run_cli("--check", "--relaxed", "--no-baseline",
                      "tests", cwd=tmp_path)
    assert relaxed.returncode == 0
    # host-sync scopes to core/serve paths, so even strict mode does
    # not fire here — but the relaxed profile must run fewer rules
    assert "across 3 rule(s)" in relaxed.stdout + relaxed.stderr
    assert "across 7 rule(s)" in strict.stdout + strict.stderr


def test_cli_write_baseline_round_trip(tmp_path):
    pkg = tmp_path / "src" / "repro" / "models"
    pkg.mkdir(parents=True)
    (pkg / "legacy.py").write_text(
        "import jax\n@jax.jit\ndef f(x):\n    print(x)\n    return x\n")
    p1 = run_cli("--check", "--no-baseline", "src", cwd=tmp_path)
    assert p1.returncode == 1
    p2 = run_cli("--write-baseline", "src", cwd=tmp_path)
    assert p2.returncode == 0
    p3 = run_cli("--check", "src", cwd=tmp_path)
    assert p3.returncode == 0, p3.stdout + p3.stderr
    assert "(1 baselined)" in p3.stdout
