"""mamba2-2.7b [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]  64L d_model=2560 vocab=50280
ssm_state=128."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, attention="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=256),
    sub_quadratic=True,
)

SMOKE = CONFIG.scaled(num_layers=4, d_model=64, vocab_size=256,
                      ssm=SSMConfig(d_state=16, head_dim=8, expand=2,
                                    d_conv=4, chunk=32))
